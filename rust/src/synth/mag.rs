//! **synth-MAG**: the OGBN-MAG substitute (DESIGN.md §Substitutions).
//!
//! A stochastic-block heterogeneous academic graph with the exact §8
//! schema: node sets `paper` / `author` / `institution` /
//! `field_of_study`, edge sets `cites` (paper→paper), `writes`
//! (author→paper), `written` (paper→author, the reverse — the sampling
//! spec of Fig. 6 traverses it), `affiliated_with` (author→institution)
//! and `has_topic` (paper→field_of_study).
//!
//! Latent structure mirrors what makes OGBN-MAG learnable:
//! * every paper belongs to a latent *topic community*;
//! * its venue **label** is drawn from a community-specific distribution
//!   (so labels are predictable from community evidence);
//! * its 128-d `feat` vector = label centroid + community centroid +
//!   Gaussian noise (so features carry signal but not the full answer);
//! * `cites` edges prefer same-community papers and older targets;
//! * authors have home communities; `writes` links them to papers of
//!   their community; `has_topic` maps communities onto fields of study;
//! * `year` gives the temporal train/validation/test split of §8.1
//!   (train: year ≤ split0, validation: = split1, test: ≥ split2).
//!
//! GNN value-add: a paper's own features give moderate accuracy; pooling
//! neighbors (cited papers, co-authored papers, fields) denoises the
//! community estimate, so message passing beats the feature-only
//! baseline — the qualitative property Table 1 relies on.

use std::collections::BTreeMap;

use crate::schema::{EdgeSetSpec, FeatureSpec, GraphSchema, Metadata, NodeSetSpec};
use crate::store::{EdgeColumn, GraphStore, NodeColumn};
use crate::util::rng::{mix64, Rng};

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct MagConfig {
    pub num_papers: usize,
    pub num_authors: usize,
    pub num_institutions: usize,
    pub num_fields: usize,
    /// Venue classes (OGBN-MAG has 349).
    pub num_classes: usize,
    /// Latent topic communities.
    pub num_communities: usize,
    /// Paper feature dimension (OGBN-MAG: 128).
    pub feature_dim: usize,
    /// Mean citations per paper.
    pub mean_citations: f64,
    /// Mean authors per paper.
    pub mean_authors_per_paper: f64,
    /// Mean fields of study per paper.
    pub mean_topics: f64,
    /// Probability a cites edge stays within the community.
    pub community_coherence: f64,
    /// Probability the venue label equals the community's modal venue.
    pub label_coherence: f64,
    /// Feature noise standard deviation.
    pub feature_noise: f32,
    /// Year range [min, max] inclusive; split: train ≤ max-2,
    /// validation = max-1, test = max (like §8.1's 2017/2018/2019).
    pub year_min: i64,
    pub year_max: i64,
    pub seed: u64,
}

impl Default for MagConfig {
    fn default() -> MagConfig {
        MagConfig {
            num_papers: 4000,
            num_authors: 6000,
            num_institutions: 200,
            num_fields: 120,
            num_classes: 20,
            num_communities: 20,
            feature_dim: 128,
            mean_citations: 8.0,
            mean_authors_per_paper: 3.0,
            mean_topics: 2.0,
            community_coherence: 0.85,
            label_coherence: 0.75,
            feature_noise: 0.8,
            year_min: 2010,
            year_max: 2019,
            seed: 17,
        }
    }
}

impl MagConfig {
    /// A tiny config for unit tests.
    pub fn tiny() -> MagConfig {
        MagConfig {
            num_papers: 120,
            num_authors: 150,
            num_institutions: 10,
            num_fields: 12,
            num_classes: 4,
            num_communities: 4,
            feature_dim: 16,
            mean_citations: 4.0,
            mean_authors_per_paper: 2.0,
            mean_topics: 1.5,
            ..MagConfig::default()
        }
    }
}

/// The generated dataset: store + task metadata.
pub struct MagDataset {
    pub store: GraphStore,
    pub config: MagConfig,
    /// Venue label per paper.
    pub labels: Vec<i64>,
    /// Publication year per paper.
    pub years: Vec<i64>,
    /// Ground-truth community (for diagnostics only; not a feature).
    pub communities: Vec<u32>,
}

/// Split membership derived from years (§8.1 temporal protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Validation,
    Test,
}

impl MagDataset {
    pub fn split_of(&self, paper: u32) -> Split {
        let y = self.years[paper as usize];
        if y <= self.config.year_max - 2 {
            Split::Train
        } else if y == self.config.year_max - 1 {
            Split::Validation
        } else {
            Split::Test
        }
    }

    /// Papers in a split.
    pub fn papers_in_split(&self, split: Split) -> Vec<u32> {
        (0..self.config.num_papers as u32).filter(|&p| self.split_of(p) == split).collect()
    }
}

/// The §8 / Figure 5 schema (appendix A.6.1), parameterized by config.
pub fn mag_schema(cfg: &MagConfig) -> GraphSchema {
    let mut paper = NodeSetSpec::default();
    paper.features.insert("feat".into(), FeatureSpec::f32(&[cfg.feature_dim]));
    paper.features.insert("labels".into(), FeatureSpec::i64(&[]));
    paper.features.insert("year".into(), FeatureSpec::i64(&[]));
    paper.metadata = Metadata {
        filename: Some("nodes-paper.gts".into()),
        cardinality: Some(cfg.num_papers as u64),
    };
    let mut author = NodeSetSpec::default();
    author.metadata =
        Metadata { filename: None, cardinality: Some(cfg.num_authors as u64) };
    // Institutions and fields of study carry only an id embedding handle
    // ("#id" in A.6.1); models learn embedding tables for them (§8.1).
    let mut institution = NodeSetSpec::default();
    institution.metadata =
        Metadata { filename: None, cardinality: Some(cfg.num_institutions as u64) };
    let mut field = NodeSetSpec::default();
    field.metadata = Metadata { filename: None, cardinality: Some(cfg.num_fields as u64) };

    let es = |src: &str, tgt: &str| EdgeSetSpec {
        source: src.into(),
        target: tgt.into(),
        features: BTreeMap::new(),
        metadata: Metadata::default(),
    };
    GraphSchema::default()
        .with_node_set("paper", paper)
        .with_node_set("author", author)
        .with_node_set("institution", institution)
        .with_node_set("field_of_study", field)
        .with_edge_set("cites", es("paper", "paper"))
        .with_edge_set("writes", es("author", "paper"))
        .with_edge_set("written", es("paper", "author"))
        .with_edge_set("affiliated_with", es("author", "institution"))
        .with_edge_set("has_topic", es("paper", "field_of_study"))
}

/// Generate the dataset.
pub fn generate(cfg: &MagConfig) -> MagDataset {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.num_communities;

    // --- latent assignments -------------------------------------------------
    // Papers → communities (Zipf-ish so communities are imbalanced like
    // real venues), years uniform.
    let communities: Vec<u32> =
        (0..cfg.num_papers).map(|_| (rng.zipf(k, 1.5) - 1) as u32).collect();
    let years: Vec<i64> = (0..cfg.num_papers)
        .map(|_| cfg.year_min + rng.uniform((cfg.year_max - cfg.year_min + 1) as usize) as i64)
        .collect();

    // Community → modal venue map (surjective onto classes, with noise).
    let modal_venue: Vec<i64> = (0..k).map(|c| (c % cfg.num_classes) as i64).collect();
    let labels: Vec<i64> = communities
        .iter()
        .map(|&c| {
            if rng.chance(cfg.label_coherence) {
                modal_venue[c as usize]
            } else {
                rng.uniform(cfg.num_classes) as i64
            }
        })
        .collect();

    // Label + community centroids for features.
    let centroid = |tag: u64, id: u64, dim: usize| -> Vec<f32> {
        let mut s = mix64(cfg.seed ^ tag, id);
        (0..dim)
            .map(|_| {
                let v = crate::util::rng::splitmix64(&mut s);
                ((v >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect()
    };
    let label_centroids: Vec<Vec<f32>> =
        (0..cfg.num_classes).map(|l| centroid(0x1abe1, l as u64, cfg.feature_dim)).collect();
    let comm_centroids: Vec<Vec<f32>> =
        (0..k).map(|c| centroid(0xc0331, c as u64, cfg.feature_dim)).collect();

    let mut feat = Vec::with_capacity(cfg.num_papers * cfg.feature_dim);
    for p in 0..cfg.num_papers {
        let lc = &label_centroids[labels[p] as usize];
        let cc = &comm_centroids[communities[p] as usize];
        for d in 0..cfg.feature_dim {
            feat.push(lc[d] + 0.5 * cc[d] + cfg.feature_noise * rng.normal());
        }
    }

    // Authors → home community, institution.
    let author_comm: Vec<u32> =
        (0..cfg.num_authors).map(|_| (rng.zipf(k, 1.5) - 1) as u32).collect();
    let author_inst: Vec<u32> =
        (0..cfg.num_authors).map(|_| rng.uniform(cfg.num_institutions) as u32).collect();

    // Community → member papers / authors (for edge sampling).
    let mut comm_papers: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (p, &c) in communities.iter().enumerate() {
        comm_papers[c as usize].push(p as u32);
    }
    let mut comm_authors: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (a, &c) in author_comm.iter().enumerate() {
        comm_authors[c as usize].push(a as u32);
    }

    // --- edges ---------------------------------------------------------------
    // cites: prefer same community and older targets.
    let mut cites = Vec::new();
    for p in 0..cfg.num_papers as u32 {
        let c = communities[p as usize] as usize;
        let n_cites = sample_count(&mut rng, cfg.mean_citations);
        for _ in 0..n_cites {
            let pool: &[u32] = if rng.chance(cfg.community_coherence) && comm_papers[c].len() > 1
            {
                &comm_papers[c]
            } else {
                &[]
            };
            let q = if pool.is_empty() {
                rng.uniform(cfg.num_papers) as u32
            } else {
                *rng.choose(pool)
            };
            if q != p && years[q as usize] <= years[p as usize] {
                cites.push((p, q));
            }
        }
    }
    cites.sort_unstable();
    cites.dedup();

    // writes: each paper gets authors from its community.
    let mut writes = Vec::new();
    for p in 0..cfg.num_papers as u32 {
        let c = communities[p as usize] as usize;
        let n_auth = 1 + sample_count(&mut rng, cfg.mean_authors_per_paper - 1.0);
        for _ in 0..n_auth {
            let a = if !comm_authors[c].is_empty() && rng.chance(cfg.community_coherence) {
                *rng.choose(&comm_authors[c])
            } else {
                rng.uniform(cfg.num_authors) as u32
            };
            writes.push((a, p));
        }
    }
    writes.sort_unstable();
    writes.dedup();

    // affiliated_with: author → their institution.
    let affiliated: Vec<(u32, u32)> =
        (0..cfg.num_authors as u32).map(|a| (a, author_inst[a as usize])).collect();

    // has_topic: community-correlated fields.
    let mut has_topic = Vec::new();
    let fields_per_comm = (cfg.num_fields / k).max(1);
    for p in 0..cfg.num_papers as u32 {
        let c = communities[p as usize] as usize;
        let n_topics = 1 + sample_count(&mut rng, cfg.mean_topics - 1.0);
        for _ in 0..n_topics {
            let f = if rng.chance(cfg.community_coherence) {
                (c * fields_per_comm + rng.uniform(fields_per_comm)) % cfg.num_fields
            } else {
                rng.uniform(cfg.num_fields)
            };
            has_topic.push((p, f as u32));
        }
    }
    has_topic.sort_unstable();
    has_topic.dedup();

    // --- assemble store ------------------------------------------------------
    let schema = mag_schema(cfg);
    let mut store = GraphStore::new(schema);

    // Column lengths are fixed by construction (`num_papers` rows
    // each), so the columns are written directly rather than through
    // the fallible `add_*` checks; `generates_valid_store` exercises
    // `validate()` over the result.
    let mut paper_col = NodeColumn::new(cfg.num_papers);
    paper_col.f32s.insert("feat".into(), (cfg.feature_dim, feat));
    paper_col.i64s.insert("labels".into(), (0, labels.clone()));
    paper_col.i64s.insert("year".into(), (0, years.clone()));
    store.nodes.insert("paper".into(), paper_col);
    store.nodes.insert("author".into(), NodeColumn::new(cfg.num_authors));
    store.nodes.insert("institution".into(), NodeColumn::new(cfg.num_institutions));
    store.nodes.insert("field_of_study".into(), NodeColumn::new(cfg.num_fields));

    let writes_col = EdgeColumn::from_edge_list("author", "paper", cfg.num_authors, &writes);
    let written_col = writes_col.reversed(cfg.num_papers);
    store.edges.insert(
        "cites".into(),
        EdgeColumn::from_edge_list("paper", "paper", cfg.num_papers, &cites),
    );
    store.edges.insert("writes".into(), writes_col);
    store.edges.insert("written".into(), written_col);
    store.edges.insert(
        "affiliated_with".into(),
        EdgeColumn::from_edge_list("author", "institution", cfg.num_authors, &affiliated),
    );
    store.edges.insert(
        "has_topic".into(),
        EdgeColumn::from_edge_list("paper", "field_of_study", cfg.num_papers, &has_topic),
    );

    MagDataset { store, config: cfg.clone(), labels, years, communities }
}

/// An edge-holdout split for link prediction: a seeded fraction of one
/// edge set removed from the message-passing store entirely (the
/// standard no-leakage protocol — held-out edges are never visible to
/// the GNN) and partitioned into train/validation/test supervision
/// pairs.
#[derive(Debug, Clone)]
pub struct EdgeHoldout {
    /// The dataset's store with the held-out edges removed from
    /// `edge_set` (all other edge sets untouched).
    pub store: GraphStore,
    /// Supervision pairs `(source, target)`, ~80/10/10 of the holdout.
    pub train: Vec<(u32, u32)>,
    pub val: Vec<(u32, u32)>,
    pub test: Vec<(u32, u32)>,
}

/// Build an [`EdgeHoldout`] over `edge_set`, deterministically in
/// `seed`. Note: only the named edge set is filtered — if the schema
/// carries its reverse as a separate edge set (like `writes`/`written`)
/// the caller must hold out both or leak; the shipped link-prediction
/// configs use `cites`, which has no reverse.
pub fn edge_holdout(
    ds: &MagDataset,
    edge_set: &str,
    fraction: f64,
    seed: u64,
) -> crate::Result<EdgeHoldout> {
    if !(fraction > 0.0 && fraction < 1.0) {
        return Err(crate::Error::Schema(format!(
            "edge_holdout: fraction {fraction} outside (0, 1)"
        )));
    }
    let col = ds.store.edge_column(edge_set)?;
    let n_src = col.offsets.len() - 1;
    let mut kept: Vec<(u32, u32)> = Vec::with_capacity(col.num_edges());
    let mut held: Vec<(u32, u32)> = Vec::new();
    let mut rng = Rng::new(mix64(seed, col.num_edges() as u64));
    for s in 0..n_src as u32 {
        for &t in col.neighbors(s) {
            if s != t && rng.chance(fraction) {
                held.push((s, t));
            } else {
                kept.push((s, t));
            }
        }
    }
    if held.len() < 3 {
        return Err(crate::Error::Schema(format!(
            "edge_holdout: only {} edges held out of {edge_set:?} — raise the \
             fraction or the graph size",
            held.len()
        )));
    }
    // ~80/10/10, each split non-empty, shuffled deterministically.
    rng.shuffle(&mut held);
    let n = held.len();
    let n_val = (n / 10).max(1);
    let n_test = (n / 10).max(1);
    let test = held.split_off(n - n_test);
    let val = held.split_off(held.len() - n_val);
    let train = held;

    let mut store = ds.store.clone();
    store.edges.insert(
        edge_set.to_string(),
        EdgeColumn::from_edge_list(&col.source_set, &col.target_set, n_src, &kept),
    );
    store.validate().map_err(|e| {
        crate::Error::Schema(format!("edge_holdout: filtered store invalid: {e}"))
    })?;
    Ok(EdgeHoldout { store, train, val, test })
}

/// Poisson-ish count with the given mean (geometric mixture — cheap and
/// adequate for degree distributions).
fn sample_count(rng: &mut Rng, mean: f64) -> usize {
    if mean <= 0.0 {
        return 0;
    }
    // Sum of two geometrics approximates a modest-variance count.
    let p = 1.0 / (1.0 + mean / 2.0);
    let mut total = 0;
    for _ in 0..2 {
        let mut n = 0;
        while !rng.chance(p) && n < 10_000 {
            n += 1;
        }
        total += n;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_store() {
        let ds = generate(&MagConfig::tiny());
        ds.store.validate().unwrap();
        assert_eq!(ds.store.node_count("paper").unwrap(), 120);
        assert_eq!(ds.store.node_count("author").unwrap(), 150);
        assert!(ds.store.edge_column("cites").unwrap().num_edges() > 50);
        assert!(ds.store.edge_column("writes").unwrap().num_edges() >= 120);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&MagConfig::tiny());
        let b = generate(&MagConfig::tiny());
        assert_eq!(a.labels, b.labels);
        assert_eq!(
            a.store.edge_column("cites").unwrap().targets,
            b.store.edge_column("cites").unwrap().targets
        );
        let mut cfg = MagConfig::tiny();
        cfg.seed = 99;
        let c = generate(&cfg);
        assert_ne!(a.labels, c.labels);
    }

    #[test]
    fn written_is_reverse_of_writes() {
        let ds = generate(&MagConfig::tiny());
        let writes = ds.store.edge_column("writes").unwrap();
        let written = ds.store.edge_column("written").unwrap();
        assert_eq!(writes.num_edges(), written.num_edges());
        // Every (a -> p) in writes appears as (p -> a) in written.
        for a in 0..ds.config.num_authors as u32 {
            for &p in writes.neighbors(a) {
                assert!(written.neighbors(p).contains(&a));
            }
        }
    }

    #[test]
    fn cites_respects_time() {
        let ds = generate(&MagConfig::tiny());
        let cites = ds.store.edge_column("cites").unwrap();
        for p in 0..ds.config.num_papers as u32 {
            for &q in cites.neighbors(p) {
                assert!(
                    ds.years[q as usize] <= ds.years[p as usize],
                    "paper can only cite same-year or older"
                );
            }
        }
    }

    #[test]
    fn splits_partition_papers() {
        let ds = generate(&MagConfig::tiny());
        let train = ds.papers_in_split(Split::Train);
        let val = ds.papers_in_split(Split::Validation);
        let test = ds.papers_in_split(Split::Test);
        assert_eq!(train.len() + val.len() + test.len(), ds.config.num_papers);
        assert!(!train.is_empty() && !val.is_empty() && !test.is_empty());
        for &p in &train {
            assert!(ds.years[p as usize] <= ds.config.year_max - 2);
        }
    }

    #[test]
    fn labels_in_range_and_correlated_with_community() {
        let ds = generate(&MagConfig::tiny());
        assert!(ds.labels.iter().all(|&l| l >= 0 && l < ds.config.num_classes as i64));
        // Label coherence: most papers of a community share its modal venue.
        let mut agree = 0;
        for p in 0..ds.config.num_papers {
            let modal = (ds.communities[p] as usize % ds.config.num_classes) as i64;
            if ds.labels[p] == modal {
                agree += 1;
            }
        }
        let frac = agree as f64 / ds.config.num_papers as f64;
        assert!(frac > 0.6, "label-community coherence {frac}");
    }

    #[test]
    fn edge_holdout_is_deterministic_and_leak_free() {
        let ds = generate(&MagConfig::tiny());
        let a = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let b = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        assert_eq!(a.train, b.train, "same seed, same split");
        assert_eq!(a.val, b.val);
        assert_eq!(a.test, b.test);
        let c = edge_holdout(&ds, "cites", 0.2, 10).unwrap();
        assert_ne!(a.train, c.train, "different seed, different split");

        // Counts: kept + held == original; splits non-empty + disjoint.
        let orig = ds.store.edge_column("cites").unwrap().num_edges();
        let kept = a.store.edge_column("cites").unwrap().num_edges();
        let held = a.train.len() + a.val.len() + a.test.len();
        assert_eq!(kept + held, orig);
        assert!(!a.train.is_empty() && !a.val.is_empty() && !a.test.is_empty());
        let all: std::collections::HashSet<(u32, u32)> =
            a.train.iter().chain(&a.val).chain(&a.test).copied().collect();
        assert_eq!(all.len(), held, "splits are disjoint");

        // No leakage: every held-out edge is gone from the train store.
        let col = a.store.edge_column("cites").unwrap();
        for &(s, t) in &all {
            assert!(!col.neighbors(s).contains(&t), "held-out edge ({s},{t}) still in store");
        }
        // Other edge sets untouched.
        assert_eq!(
            a.store.edge_column("writes").unwrap().num_edges(),
            ds.store.edge_column("writes").unwrap().num_edges()
        );
        a.store.validate().unwrap();
    }

    #[test]
    fn edge_holdout_rejects_bad_fractions() {
        let ds = generate(&MagConfig::tiny());
        assert!(edge_holdout(&ds, "cites", 0.0, 9).is_err());
        assert!(edge_holdout(&ds, "cites", 1.0, 9).is_err());
        assert!(edge_holdout(&ds, "no_such_set", 0.2, 9).is_err());
    }

    #[test]
    fn features_carry_label_signal() {
        // Nearest-centroid on the generated features should beat chance
        // by a wide margin — this is what makes the dataset learnable.
        let cfg = MagConfig::tiny();
        let ds = generate(&cfg);
        let col = ds.store.node_column("paper").unwrap();
        let (dim, feat) = &col.f32s["feat"];
        // Per-label centroid of the train papers.
        let mut sums = vec![0.0f64; cfg.num_classes * dim];
        let mut counts = vec![0usize; cfg.num_classes];
        for &p in &ds.papers_in_split(Split::Train) {
            let l = ds.labels[p as usize] as usize;
            counts[l] += 1;
            for d in 0..*dim {
                sums[l * dim + d] += feat[p as usize * dim + d] as f64;
            }
        }
        let mut correct = 0;
        let test = ds.papers_in_split(Split::Test);
        for &p in &test {
            let mut best = (f64::MAX, 0usize);
            for l in 0..cfg.num_classes {
                if counts[l] == 0 {
                    continue;
                }
                let mut dist = 0.0;
                for d in 0..*dim {
                    let c = sums[l * dim + d] / counts[l] as f64;
                    let x = feat[p as usize * dim + d] as f64 - c;
                    dist += x * x;
                }
                if dist < best.0 {
                    best = (dist, l);
                }
            }
            if best.1 == ds.labels[p as usize] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        let chance = 1.0 / cfg.num_classes as f64;
        assert!(acc > 2.0 * chance, "nearest-centroid acc {acc} vs chance {chance}");
    }
}
