//! The recommendation-system example graph of Figure 2 / appendix A.1.
//!
//! Used by the `recsys_spending` example and by data-model tests: it is
//! the paper's own worked example, so reproducing its tensors exactly
//! (including the `[4, 2]` flight→Yumiko edge) is a correctness check on
//! the whole data model.

use crate::graph::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use crate::Result;

/// Build the exact Figure 2b / appendix A.1 GraphTensor.
pub fn recsys_example_graph() -> Result<GraphTensor> {
    let items = NodeSet::new(vec![6])
        .with_feature(
            "category",
            Feature::str_vec(vec!["food", "show ticket", "shoes", "book", "flight", "groceries"]),
        )
        .with_feature(
            "price",
            Feature::ragged_f32(vec![
                vec![22.34, 23.42, 12.99],
                vec![27.99, 34.50],
                vec![89.99],
                vec![24.99, 45.00],
                vec![350.00],
                vec![45.13, 79.80, 12.35],
            ]),
        );
    let users = NodeSet::new(vec![4])
        .with_feature("name", Feature::str_vec(vec!["Shawn", "Jeorg", "Yumiko", "Sophie"]))
        .with_feature("age", Feature::i64_vec(vec![24, 32, 27, 38]))
        .with_feature("country", Feature::i64_vec(vec![3, 2, 1, 0]));
    let purchased = EdgeSet::new(
        vec![7],
        Adjacency {
            source_set: "items".into(),
            target_set: "users".into(),
            source: vec![0, 1, 2, 3, 4, 5, 5],
            target: vec![1, 1, 0, 0, 2, 3, 0],
        },
    );
    let is_friend = EdgeSet::new(
        vec![3],
        Adjacency {
            source_set: "users".into(),
            target_set: "users".into(),
            source: vec![1, 2, 3],
            target: vec![0, 0, 0],
        },
    );
    let context = Context::default()
        .with_feature("scores", Feature::f32_mat(4, vec![0.45, 0.98, 0.10, 0.25]));
    GraphTensor::from_pieces(
        context,
        [("items".to_string(), items), ("users".to_string(), users)].into(),
        [("purchased".to_string(), purchased), ("is-friend".to_string(), is_friend)].into(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_appendix_a1() {
        let g = recsys_example_graph().unwrap();
        assert_eq!(g.num_nodes("items").unwrap(), 6);
        assert_eq!(g.num_nodes("users").unwrap(), 4);
        assert_eq!(g.num_edges("purchased").unwrap(), 7);
        assert_eq!(g.num_edges("is-friend").unwrap(), 3);
        let scores = g.context.feature("scores").unwrap();
        let (dims, data) = scores.as_f32().unwrap();
        assert_eq!(dims, &[4]);
        assert_eq!(data, &[0.45, 0.98, 0.10, 0.25]);
    }
}
