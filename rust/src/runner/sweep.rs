//! Hyper-parameter sweep — the Vizier-study analog (appendix A.6.3).
//!
//! The paper's study searched `message_dim`, `reduce_type`,
//! `l2_regularization` ∈ [1e-6, 1e-4] (log), `dropout` ∈ {0.1, 0.2,
//! 0.3} and `use_layer_normalization`, maximizing validation accuracy.
//! Architecture-shaping knobs (`message_dim`, `reduce_type`,
//! layer-norm) are baked into the AOT artifact per config, so this
//! harness sweeps the *runtime* subspace — learning rate, dropout and
//! weight decay (the l2 analog) — plus any extra archs present in the
//! manifest, and reports the top trials by validation accuracy, like
//! the study's "top-3 configs" summary.

use std::path::{Path, PathBuf};

use super::{run_in_env, MagEnv, RunConfig};
use crate::runtime::batch::RootTask;
use crate::runtime::Runtime;
use crate::train::{Hyperparams, Trainer};
use crate::Result;

/// One trial's outcome.
#[derive(Debug, Clone)]
pub struct Trial {
    pub hp: Hyperparams,
    pub best_val_acc: f64,
    pub test_acc: f64,
}

/// Sweep configuration: the grid, and per-trial training effort.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub base: RunConfig,
    pub learning_rates: Vec<f32>,
    pub dropouts: Vec<f32>,
    pub weight_decays: Vec<f32>,
}

impl SweepConfig {
    /// The A.6.3-shaped default grid over the runtime subspace.
    pub fn default_grid(base: RunConfig) -> SweepConfig {
        SweepConfig {
            base,
            learning_rates: vec![3e-4, 1e-3, 3e-3],
            dropouts: vec![0.1, 0.2, 0.3],
            weight_decays: vec![1e-6, 1e-5, 1e-4],
        }
    }

    pub fn num_trials(&self) -> usize {
        self.learning_rates.len() * self.dropouts.len() * self.weight_decays.len()
    }
}

/// Per-trial journal path derived from the sweep's base `--events-out`
/// (`sweep.jsonl` → `sweep-trial003.jsonl`): every trial gets its own
/// `tfgnn_events_v1` file, ready for `tfgnn runs diff`.
pub fn trial_events_path(base: &Path, trial: usize) -> PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("events");
    let ext = base.extension().and_then(|s| s.to_str()).unwrap_or("jsonl");
    base.with_file_name(format!("{stem}-trial{trial:03}.{ext}"))
}

/// Run the grid; returns trials sorted by validation accuracy
/// (descending), like a Vizier study summary.
///
/// Compiles the trainer **once** and `reset()`s it per trial — HLO
/// compilation dominates short trials otherwise (see EXPERIMENTS §Perf).
pub fn sweep(cfg: &SweepConfig) -> Result<Vec<Trial>> {
    let env = MagEnv::from_artifacts(&cfg.base.artifacts_dir)?;
    let entry = env.manifest.model(&cfg.base.arch)?.clone();
    let hp0 = Hyperparams::from_manifest(&env.manifest)?;
    let mut trainer = Trainer::new(
        Runtime::cpu()?,
        &cfg.base.artifacts_dir,
        &entry,
        RootTask::default(),
        hp0,
    )?;
    let mut trials = Vec::with_capacity(cfg.num_trials());
    for &lr in &cfg.learning_rates {
        for &dropout in &cfg.dropouts {
            for &wd in &cfg.weight_decays {
                let hp = Hyperparams { learning_rate: lr, dropout, weight_decay: wd };
                let mut rc = cfg.base.clone();
                rc.hp = Some(hp);
                rc.checkpoint = None;
                if let Some(base) = &cfg.base.events_out {
                    rc.events_out = Some(trial_events_path(base, trials.len()));
                }
                trainer.reset()?;
                let report = run_in_env(&rc, &env, &mut trainer)?;
                if cfg.base.verbose {
                    println!(
                        "trial lr={lr:.0e} dropout={dropout} wd={wd:.0e}: val {:.4} test {:.4}",
                        report.best_val_acc,
                        report.test.accuracy()
                    );
                }
                trials.push(Trial {
                    hp,
                    best_val_acc: report.best_val_acc,
                    test_acc: report.test.accuracy(),
                });
            }
        }
    }
    trials.sort_by(|a, b| b.best_val_acc.total_cmp(&a.best_val_acc));
    Ok(trials)
}

/// Format the study summary (top-k table).
pub fn format_top(trials: &[Trial], k: usize) -> String {
    let mut s = String::from("rank  lr        dropout  weight_decay  val_acc  test_acc\n");
    for (i, t) in trials.iter().take(k).enumerate() {
        s.push_str(&format!(
            "{:>4}  {:<8.0e}  {:<7}  {:<12.0e}  {:.4}   {:.4}\n",
            i + 1,
            t.hp.learning_rate,
            t.hp.dropout,
            t.hp.weight_decay,
            t.best_val_acc,
            t.test_acc
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size() {
        let cfg = SweepConfig::default_grid(RunConfig::new("/tmp", "mpnn"));
        assert_eq!(cfg.num_trials(), 27);
    }

    #[test]
    fn trial_events_paths_are_distinct_siblings() {
        let base = Path::new("/tmp/out/sweep.jsonl");
        assert_eq!(trial_events_path(base, 0), Path::new("/tmp/out/sweep-trial000.jsonl"));
        assert_eq!(trial_events_path(base, 12), Path::new("/tmp/out/sweep-trial012.jsonl"));
        let bare = Path::new("events");
        assert_eq!(trial_events_path(bare, 3), Path::new("events-trial003.jsonl"));
    }

    #[test]
    fn format_top_table() {
        let trials = vec![
            Trial {
                hp: Hyperparams { learning_rate: 1e-3, dropout: 0.2, weight_decay: 1e-5 },
                best_val_acc: 0.51,
                test_acc: 0.50,
            },
            Trial {
                hp: Hyperparams { learning_rate: 3e-4, dropout: 0.1, weight_decay: 1e-6 },
                best_val_acc: 0.44,
                test_acc: 0.43,
            },
        ];
        let s = format_top(&trials, 3);
        assert!(s.contains("0.5100"));
        assert!(s.lines().count() >= 3);
    }
}
