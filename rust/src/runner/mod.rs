//! The Orchestrator (API Level 4, paper §5 / §8.4 / A.5–A.6.4).
//!
//! [`run`] is the analog of `runner.run(...)`: it wires a dataset
//! provider (sampling synth-MAG on demand or reading shards), the
//! padding/batching pipeline, the task
//! (`RootNodeMulticlassClassification` on papers), the AOT trainer, and
//! per-epoch validation into one call, returning the run history.
//! [`sweep`] is the Vizier-study analog (A.6.3): a deterministic search
//! over the runtime hyper-parameter space reporting the top trials by
//! validation accuracy.

pub mod sweep;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::graph::pad::{fit_or_skip, PadSpec};
use crate::pipeline::{epoch_stream, DatasetProvider, PipelineConfig, SamplingProvider};
use crate::runtime::batch::RootTask;
use crate::runtime::manifest::Manifest;
use crate::runtime::Runtime;
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::spec::mag_sampling_spec_sized;
use crate::sampler::SamplerConfig;
use crate::store::GraphStore;
use crate::synth::mag::{generate, MagDataset, Split};
use crate::train::metrics::EpochMetrics;
use crate::train::{Hyperparams, Trainer};
use crate::{Error, Result};

/// Orchestrator configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub arch: String,
    pub epochs: usize,
    /// Cap train steps per epoch (None = full epoch).
    pub max_steps_per_epoch: Option<usize>,
    /// Cap eval batches (None = full split).
    pub max_eval_batches: Option<usize>,
    /// Hyper-parameter override (None = manifest defaults).
    pub hp: Option<Hyperparams>,
    /// Pipeline shuffle seed.
    pub shuffle_seed: u64,
    /// Threads for the merge+pad prep stage.
    pub prep_threads: usize,
    /// Threads for the sampling stage (0/1 = serial).
    pub sampler_threads: usize,
    /// Where to write the final checkpoint (None = skip).
    pub checkpoint: Option<PathBuf>,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl RunConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, arch: &str) -> RunConfig {
        RunConfig {
            artifacts_dir: artifacts_dir.into(),
            arch: arch.to_string(),
            epochs: 3,
            max_steps_per_epoch: None,
            max_eval_batches: None,
            hp: None,
            shuffle_seed: 0x7f4a,
            prep_threads: 0,
            sampler_threads: 0,
            checkpoint: None,
            verbose: false,
        }
    }
}

/// One epoch's results.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    pub train: EpochMetrics,
    pub val: EpochMetrics,
    pub skipped_batches: u64,
    pub wall_secs: f64,
}

/// Full run results.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub arch: String,
    pub param_count: usize,
    pub epochs: Vec<EpochReport>,
    pub best_val_acc: f64,
    pub test: EpochMetrics,
    pub train_steps_per_sec: f64,
}

/// Shared assembly of dataset + sampler + pad spec from the manifest.
pub struct MagEnv {
    pub manifest: Manifest,
    pub dataset: MagDataset,
    pub store: Arc<GraphStore>,
    pub sampler: Arc<InMemorySampler>,
    pub pad: PadSpec,
    pub batch_size: usize,
}

impl MagEnv {
    pub fn from_artifacts(dir: &std::path::Path) -> Result<MagEnv> {
        let manifest = Manifest::load(dir)?;
        let mag_cfg = manifest.mag_config()?;
        let dataset = generate(&mag_cfg);
        let store = Arc::new(dataset.store.clone());
        let spec = mag_sampling_spec_sized(&store.schema, &manifest.sampling_sizes()?)?;
        let sampler =
            Arc::new(InMemorySampler::new(store.clone(), spec, manifest.plan_seed()?)?);
        let pad = manifest.pad_spec()?;
        let batch_size = manifest.batch_size()?;
        Ok(MagEnv { manifest, dataset, store, sampler, pad, batch_size })
    }

    /// Batch up a seed list for evaluation (merge + fit-or-skip).
    pub fn eval_batches(
        &self,
        seeds: &[u32],
        limit: Option<usize>,
    ) -> impl Iterator<Item = Result<Option<crate::graph::pad::Padded>>> + '_ {
        let batch = self.batch_size;
        let n = limit.map(|l| l * batch).unwrap_or(usize::MAX);
        let seeds: Vec<u32> = seeds.iter().copied().take(n).collect();
        let pad = self.pad.clone();
        let sampler = Arc::clone(&self.sampler);
        seeds
            .chunks(batch)
            .map(|c| c.to_vec())
            .collect::<Vec<_>>()
            .into_iter()
            .filter(move |c| c.len() == batch)
            .map(move |chunk| {
                let graphs = chunk
                    .iter()
                    .map(|&s| sampler.sample(s))
                    .collect::<Result<Vec<_>>>()?;
                let merged = crate::graph::batch::merge(&graphs)?;
                Ok(fit_or_skip(&merged, &pad))
            })
    }
}

/// Train + validate + test — the `runner.run(...)` entry point.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    let env = MagEnv::from_artifacts(&cfg.artifacts_dir)?;
    let entry = env.manifest.model(&cfg.arch)?.clone();
    let hp = match cfg.hp {
        Some(hp) => hp,
        None => Hyperparams::from_manifest(&env.manifest)?,
    };
    let rt = Runtime::cpu()?;
    let mut trainer =
        Trainer::new(rt, &cfg.artifacts_dir, &entry, RootTask::default(), hp)?;
    run_in_env(cfg, &env, &mut trainer)
}

/// [`run`] against a pre-built environment and trainer — lets the sweep
/// reuse one compiled trainer across trials (`Trainer::reset` between).
pub fn run_in_env(cfg: &RunConfig, env: &MagEnv, trainer: &mut Trainer) -> Result<RunReport> {
    let entry = env.manifest.model(&cfg.arch)?.clone();
    if let Some(hp) = cfg.hp {
        trainer.hp = hp;
    }

    let train_seeds = env.dataset.papers_in_split(Split::Train);
    let val_seeds = env.dataset.papers_in_split(Split::Validation);
    let test_seeds = env.dataset.papers_in_split(Split::Test);
    if cfg.verbose {
        println!(
            "runner: arch={} params={} train/val/test = {}/{}/{} papers",
            cfg.arch,
            entry.param_count,
            train_seeds.len(),
            val_seeds.len(),
            test_seeds.len()
        );
    }

    let provider = Arc::new(SamplingProvider {
        sampler: Arc::clone(&env.sampler),
        seeds: train_seeds,
        shuffle_seed: cfg.shuffle_seed,
        sampling: SamplerConfig::with_threads(cfg.sampler_threads),
    });
    let mut pipe_cfg = PipelineConfig::new(env.batch_size, env.pad.clone());
    pipe_cfg.shuffle_buffer = 4 * env.batch_size;
    pipe_cfg.shuffle_seed = cfg.shuffle_seed;
    pipe_cfg.prep_threads = cfg.prep_threads;

    let mut epochs = Vec::new();
    let mut best_val_acc = 0.0f64;
    let mut total_steps = 0u64;
    let mut total_step_secs = 0.0f64;
    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let stream = epoch_stream(
            Arc::clone(&provider) as Arc<dyn DatasetProvider>,
            pipe_cfg.clone(),
            epoch as u64,
        )?;
        let mut train_metrics = EpochMetrics::default();
        for padded in stream.iter() {
            let ts = Instant::now();
            let m = trainer.train_batch(&padded)?;
            total_step_secs += ts.elapsed().as_secs_f64();
            total_steps += 1;
            train_metrics.add(m);
            if let Some(max) = cfg.max_steps_per_epoch {
                if train_metrics.steps >= max {
                    break;
                }
            }
        }
        let skipped =
            stream.stats.batches_skipped.load(std::sync::atomic::Ordering::Relaxed);
        drop(stream);

        let mut val_metrics = EpochMetrics::default();
        for padded in env.eval_batches(&val_seeds, cfg.max_eval_batches) {
            if let Some(p) = padded? {
                val_metrics.add(trainer.eval_batch(&p)?);
            }
        }
        best_val_acc = best_val_acc.max(val_metrics.accuracy());
        let report = EpochReport {
            epoch,
            train: train_metrics,
            val: val_metrics,
            skipped_batches: skipped,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            println!(
                "epoch {:>2}: train {} | val {} | skipped {} | {:.1}s",
                epoch, report.train, report.val, skipped, report.wall_secs
            );
        }
        epochs.push(report);
    }

    let mut test = EpochMetrics::default();
    for padded in env.eval_batches(&test_seeds, cfg.max_eval_batches) {
        if let Some(p) = padded? {
            test.add(trainer.eval_batch(&p)?);
        }
    }
    if cfg.verbose {
        println!("test: {test}");
    }

    if let Some(path) = &cfg.checkpoint {
        let params = trainer.params_to_host()?;
        crate::train::checkpoint::save(path, &params)?;
        if cfg.verbose {
            println!("checkpoint written to {}", path.display());
        }
    }

    if epochs.is_empty() {
        return Err(Error::Pipeline("0 epochs requested".into()));
    }
    Ok(RunReport {
        arch: cfg.arch.clone(),
        param_count: entry.param_count,
        epochs,
        best_val_acc,
        test,
        train_steps_per_sec: if total_step_secs > 0.0 {
            total_steps as f64 / total_step_secs
        } else {
            0.0
        },
    })
}
