//! The Orchestrator (API Level 4, paper §5 / §8.4 / A.5–A.6.4).
//!
//! [`run`] is the analog of `runner.run(...)`: it wires a dataset
//! provider (sampling synth-MAG on demand or reading shards), the
//! padding/batching pipeline, the task
//! (`RootNodeMulticlassClassification` on papers), a trainer, and
//! per-epoch validation into one call, returning the run history.
//! [`sweep`] is the Vizier-study analog (A.6.3): a deterministic search
//! over the runtime hyper-parameter space reporting the top trials by
//! validation accuracy.
//!
//! Two interchangeable **training engines** ([`TrainEngine`]) sit
//! behind the same epoch loop:
//! * `aot` — the compiled HLO/PJRT [`Trainer`] (needs `make artifacts`);
//! * `native` — the pure-Rust reverse-mode
//!   [`crate::train::native::NativeTrainer`], which needs no artifacts
//!   at all: pass `RunConfig::config_path` pointing at a raw
//!   `configs/*.json` and the whole train loop runs offline,
//!   data-parallel over `trainer_threads` replicas.

pub mod sweep;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::graph::pad::{fit_or_skip, PadSpec, Padded};
use crate::obs::events::{self, EventJournal, GradStats, RunStart, StepEvent, Telemetry};
use crate::obs::flight::FlightRecorder;
use crate::obs::metrics::names as metric_names;
use crate::ops::model_ref::ModelConfig;
use crate::pipeline::{epoch_stream, DatasetProvider, PipelineConfig, SamplingProvider};
use crate::runtime::batch::RootTask;
use crate::runtime::manifest::Manifest;
use crate::runtime::Runtime;
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::spec::mag_sampling_spec_sized;
use crate::sampler::SamplerConfig;
use crate::store::GraphStore;
use crate::synth::mag::{edge_holdout, generate, MagDataset, Split};
use crate::tasks::link_prediction::{pair_eval_batches, PairProvider};
use crate::train::metrics::EpochMetrics;
use crate::train::native::{AdamConfig, NativeModel, NativeTrainer};
use crate::train::{Hyperparams, StepMetrics, Trainer};
use crate::{Error, Result};

/// Which training engine executes the step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// AOT HLO programs on the PJRT runtime (requires `make artifacts`).
    #[default]
    Aot,
    /// Pure-Rust reverse-mode engine (`train::native`), artifact-free.
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "aot" => Ok(EngineKind::Aot),
            "native" => Ok(EngineKind::Native),
            other => Err(Error::Runtime(format!(
                "unknown engine {other:?} (want aot|native)"
            ))),
        }
    }
}

/// A training engine the epoch loop can drive: one train step, one
/// eval step, one checkpoint write.
pub trait TrainEngine {
    fn train_batch(&mut self, padded: &Padded) -> Result<StepMetrics>;
    fn eval_batch(&mut self, padded: &Padded) -> Result<StepMetrics>;
    fn write_checkpoint(&self, path: &Path) -> Result<()>;

    /// Install trainer telemetry (gradient probes, sentinel limit,
    /// incident recorder, journal handle for the incident tail).
    /// Engines without gradient access ignore it — their journals
    /// simply carry no grad fields.
    fn set_telemetry(&mut self, _telemetry: Telemetry) {}

    /// The most recent train step's gradient-health stats, if this
    /// engine computed them (the native engine with probes on).
    fn take_grad_stats(&mut self) -> Option<GradStats> {
        None
    }
}

impl TrainEngine for Trainer {
    fn train_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        Trainer::train_batch(self, padded)
    }

    fn eval_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        Trainer::eval_batch(self, padded)
    }

    fn write_checkpoint(&self, path: &Path) -> Result<()> {
        let params = self.params_to_host()?;
        crate::train::checkpoint::save(path, &params)
    }
}

impl TrainEngine for NativeTrainer {
    fn train_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        NativeTrainer::train_batch(self, padded)
    }

    fn eval_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        NativeTrainer::eval_batch(self, padded)
    }

    fn write_checkpoint(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn set_telemetry(&mut self, telemetry: Telemetry) {
        NativeTrainer::set_telemetry(self, telemetry)
    }

    fn take_grad_stats(&mut self) -> Option<GradStats> {
        NativeTrainer::take_grad_stats(self)
    }
}

/// Orchestrator configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub arch: String,
    pub epochs: usize,
    /// Cap train steps per epoch (None = full epoch).
    pub max_steps_per_epoch: Option<usize>,
    /// Cap eval batches (None = full split).
    pub max_eval_batches: Option<usize>,
    /// Hyper-parameter override (None = manifest defaults).
    pub hp: Option<Hyperparams>,
    /// Pipeline shuffle seed.
    pub shuffle_seed: u64,
    /// Threads for the merge+pad prep stage.
    pub prep_threads: usize,
    /// Threads for the sampling stage (0/1 = serial).
    pub sampler_threads: usize,
    /// Which engine runs the train/eval steps.
    pub engine: EngineKind,
    /// Replica threads for the native engine (0/1 = serial).
    pub trainer_threads: usize,
    /// Raw config JSON for the native engine when no `artifacts/`
    /// manifest exists (e.g. `configs/mag_small.json`).
    pub config_path: Option<PathBuf>,
    /// Where to write the final checkpoint (None = skip).
    pub checkpoint: Option<PathBuf>,
    /// Append the `tfgnn_events_v1` step journal here (None = off).
    pub events_out: Option<PathBuf>,
    /// Gradient-explosion sentinel threshold: error out with a
    /// structured diagnostic when the global gradient L2 norm exceeds
    /// this (None = sentinel off; non-finite gradients always trip).
    pub grad_norm_limit: Option<f64>,
    /// Directory for gradient-health incident dumps (None = off).
    pub incident_dir: Option<PathBuf>,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl RunConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, arch: &str) -> RunConfig {
        RunConfig {
            artifacts_dir: artifacts_dir.into(),
            arch: arch.to_string(),
            epochs: 3,
            max_steps_per_epoch: None,
            max_eval_batches: None,
            hp: None,
            shuffle_seed: 0x7f4a,
            prep_threads: 0,
            sampler_threads: 0,
            engine: EngineKind::Aot,
            trainer_threads: 0,
            config_path: None,
            checkpoint: None,
            events_out: None,
            grad_norm_limit: None,
            incident_dir: None,
            verbose: false,
        }
    }
}

/// One epoch's results.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    pub train: EpochMetrics,
    pub val: EpochMetrics,
    pub skipped_batches: u64,
    pub wall_secs: f64,
}

/// Full run results.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub arch: String,
    pub param_count: usize,
    pub epochs: Vec<EpochReport>,
    pub best_val_acc: f64,
    pub test: EpochMetrics,
    pub train_steps_per_sec: f64,
}

/// Shared assembly of dataset + sampler + pad spec from the manifest.
pub struct MagEnv {
    pub manifest: Manifest,
    pub dataset: MagDataset,
    pub store: Arc<GraphStore>,
    pub sampler: Arc<InMemorySampler>,
    pub pad: PadSpec,
    pub batch_size: usize,
}

impl MagEnv {
    pub fn from_artifacts(dir: &std::path::Path) -> Result<MagEnv> {
        MagEnv::from_manifest(Manifest::load(dir)?)
    }

    /// Build the environment from an already-parsed manifest — also
    /// usable with a manifest synthesized from a raw config file (see
    /// [`manifest_from_config_file`]), which has an empty model table.
    pub fn from_manifest(manifest: Manifest) -> Result<MagEnv> {
        let mag_cfg = manifest.mag_config()?;
        let dataset = generate(&mag_cfg);
        let store = Arc::new(dataset.store.clone());
        let spec = mag_sampling_spec_sized(&store.schema, &manifest.sampling_sizes()?)?;
        let sampler =
            Arc::new(InMemorySampler::new(store.clone(), spec, manifest.plan_seed()?)?);
        let pad = manifest.pad_spec()?;
        let batch_size = manifest.batch_size()?;
        Ok(MagEnv { manifest, dataset, store, sampler, pad, batch_size })
    }

    /// Batch up a seed list for evaluation (merge + fit-or-skip).
    pub fn eval_batches(
        &self,
        seeds: &[u32],
        limit: Option<usize>,
    ) -> impl Iterator<Item = Result<Option<crate::graph::pad::Padded>>> + '_ {
        let batch = self.batch_size;
        let n = limit.map(|l| l * batch).unwrap_or(usize::MAX);
        let seeds: Vec<u32> = seeds.iter().copied().take(n).collect();
        let pad = self.pad.clone();
        let sampler = Arc::clone(&self.sampler);
        seeds
            .chunks(batch)
            .map(|c| c.to_vec())
            .collect::<Vec<_>>()
            .into_iter()
            .filter(move |c| c.len() == batch)
            .map(move |chunk| {
                let graphs = chunk
                    .iter()
                    .map(|&s| sampler.sample(s))
                    .collect::<Result<Vec<_>>>()?;
                let merged = crate::graph::batch::merge(&graphs)?;
                Ok(fit_or_skip(&merged, &pad))
            })
    }
}

/// A manifest with no lowered models, synthesized from a raw run
/// config (`configs/*.json`) — enough for the native engine, which
/// needs only the config side (dataset, schema, sampling, pad, model
/// dims, train hyper-parameters).
pub fn manifest_from_config_file(path: &Path) -> Result<Manifest> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Runtime(format!("{}: {e}", path.display())))?;
    Ok(Manifest {
        config: crate::util::json::Json::parse(&text)?,
        models: std::collections::BTreeMap::new(),
    })
}

/// Train + validate + test — the `runner.run(...)` entry point.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    match cfg.engine {
        EngineKind::Aot => {
            let env = MagEnv::from_artifacts(&cfg.artifacts_dir)?;
            let entry = env.manifest.model(&cfg.arch)?.clone();
            let hp = match cfg.hp {
                Some(hp) => hp,
                None => Hyperparams::from_manifest(&env.manifest)?,
            };
            let rt = Runtime::cpu()?;
            let mut trainer =
                Trainer::new(rt, &cfg.artifacts_dir, &entry, RootTask::default(), hp)?;
            run_in_env(cfg, &env, &mut trainer)
        }
        EngineKind::Native => run_native(cfg),
    }
}

/// Optimizer hyper-parameters + init seed for the native engine, from
/// the manifest config plus any CLI override.
fn native_hyperparams(cfg: &RunConfig, manifest: &Manifest) -> Result<(AdamConfig, u64)> {
    let init_seed = manifest
        .config
        .get("train")?
        .opt("init_seed")
        .and_then(|v| v.as_i64().ok())
        .unwrap_or(3) as u64;
    let mut adam = AdamConfig::from_train_config(&manifest.config)?;
    if let Some(hp) = cfg.hp {
        adam.lr = hp.learning_rate;
        adam.weight_decay = hp.weight_decay;
        // The native engine runs the deterministic (eval-mode) forward:
        // there is no dropout op to apply, so a requested rate would
        // otherwise vanish silently — say so once, loudly.
        if hp.dropout > 0.0 {
            eprintln!(
                "warning: native engine ignores dropout={} (deterministic \
                 forward; only lr/weight_decay apply)",
                hp.dropout
            );
        }
    }
    Ok((adam, init_seed))
}

/// Resolved hyper-parameters for the journal's `run_start` header:
/// the CLI override when given, else the manifest's train block
/// (native configs without a `model.dropout` key fall back to the
/// individual train keys, zero where absent — header metadata only,
/// never fed into the update).
fn header_hyperparams(cfg: &RunConfig, manifest: &Manifest) -> Hyperparams {
    if let Some(hp) = cfg.hp {
        return hp;
    }
    if let Ok(hp) = Hyperparams::from_manifest(manifest) {
        return hp;
    }
    let get = |key: &str| {
        manifest
            .config
            .opt("train")
            .and_then(|t| t.opt(key))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0) as f32
    };
    Hyperparams {
        learning_rate: get("learning_rate"),
        dropout: 0.0,
        weight_decay: get("weight_decay"),
    }
}

/// The native-engine run path: no AOT artifacts required. Reads the
/// manifest from `artifacts_dir` when present, else the raw config at
/// `config_path`. The config's `task` block selects the objective:
/// root classification and graph regression ride the seed-rooted
/// pipeline; link prediction builds its edge-holdout split and trains
/// over pair subgraphs.
pub fn run_native(cfg: &RunConfig) -> Result<RunReport> {
    let manifest = match &cfg.config_path {
        Some(p) => manifest_from_config_file(p)?,
        None => Manifest::load(&cfg.artifacts_dir)?,
    };
    // Fail fast with the same structured diagnostics `tfgnn check`
    // prints — nothing (dataset, store, model) is built past a bad
    // config.
    crate::analysis::check_config(&manifest.config)?;
    let model_cfg = ModelConfig::from_manifest(&manifest)?;
    if model_cfg.task.kind == "link_prediction" {
        return run_native_linkpred(cfg, manifest, model_cfg);
    }
    let env = MagEnv::from_manifest(manifest)?;
    let (adam, init_seed) = native_hyperparams(cfg, &env.manifest)?;
    let model = NativeModel::init(model_cfg, init_seed)?;
    let task = crate::tasks::build(&model.cfg)?;
    let param_count = model.param_elems();
    let mut trainer = NativeTrainer::with_task(model, adam, task, cfg.trainer_threads);
    run_loop(cfg, &env, &mut trainer, param_count)
}

/// The link-prediction run path: hold a seeded fraction of the task's
/// edge set out of the message-passing store, train over pair
/// subgraphs of the held-out train pairs, evaluate MRR/hits@k on the
/// held-out validation/test pairs.
fn run_native_linkpred(
    cfg: &RunConfig,
    manifest: Manifest,
    model_cfg: ModelConfig,
) -> Result<RunReport> {
    let tcfg = model_cfg.task.clone();
    let tcfg_kind = tcfg.kind.clone();
    let mag_cfg = manifest.mag_config()?;
    let dataset = generate(&mag_cfg);
    let holdout =
        edge_holdout(&dataset, &tcfg.edge_set, tcfg.holdout_fraction, tcfg.split_seed)?;
    let store = Arc::new(holdout.store);
    let spec = mag_sampling_spec_sized(&store.schema, &manifest.sampling_sizes()?)?;
    let sampler =
        Arc::new(InMemorySampler::new(Arc::clone(&store), spec, manifest.plan_seed()?)?);
    let pad = manifest.pad_spec()?;
    let batch_size = manifest.batch_size()?;
    let node_set = model_cfg
        .edge_endpoints
        .get(&tcfg.edge_set)
        .map(|(s, _)| s.clone())
        .ok_or_else(|| {
            Error::Schema(format!("task.edge_set {:?} is not in the schema", tcfg.edge_set))
        })?;
    let num_nodes = store.node_count(&node_set)?;
    let (adam, init_seed) = native_hyperparams(cfg, &manifest)?;
    let model = NativeModel::init(model_cfg, init_seed)?;
    let task = crate::tasks::build(&model.cfg)?;
    let param_count = model.param_elems();
    let mut trainer = NativeTrainer::with_task(model, adam, task, cfg.trainer_threads);

    let provider = Arc::new(PairProvider {
        sampler: Arc::clone(&sampler),
        pairs: holdout.train.clone(),
        shuffle_seed: cfg.shuffle_seed,
        negatives: tcfg.negatives,
        neg_seed: tcfg.split_seed,
        num_nodes,
        sampling: SamplerConfig::with_threads(cfg.sampler_threads),
    });
    let split_sizes = [holdout.train.len(), holdout.val.len(), holdout.test.len()];
    let (val_pairs, test_pairs) = (holdout.val, holdout.test);
    let (s_val, s_test) = (Arc::clone(&sampler), Arc::clone(&sampler));
    let (pad_val, pad_test) = (pad.clone(), pad.clone());
    let (negatives, neg_seed) = (tcfg.negatives, tcfg.split_seed);
    let data = RunData {
        provider,
        batch_size,
        pad,
        split_sizes,
        task_kind: tcfg_kind,
        hp: header_hyperparams(cfg, &manifest),
        val: Box::new(move |limit| {
            Box::new(pair_eval_batches(
                Arc::clone(&s_val),
                val_pairs.clone(),
                batch_size,
                pad_val.clone(),
                negatives,
                neg_seed,
                num_nodes,
                limit,
            ))
        }),
        test: Box::new(move |limit| {
            Box::new(pair_eval_batches(
                Arc::clone(&s_test),
                test_pairs.clone(),
                batch_size,
                pad_test.clone(),
                negatives,
                neg_seed,
                num_nodes,
                limit,
            ))
        }),
    };
    run_data_loop(cfg, data, &mut trainer, param_count)
}

/// [`run`] against a pre-built environment and AOT trainer — lets the
/// sweep reuse one compiled trainer across trials (`Trainer::reset`
/// between).
pub fn run_in_env(cfg: &RunConfig, env: &MagEnv, trainer: &mut Trainer) -> Result<RunReport> {
    let entry = env.manifest.model(&cfg.arch)?.clone();
    if let Some(hp) = cfg.hp {
        trainer.hp = hp;
    }
    run_loop(cfg, env, trainer, entry.param_count)
}

/// Lazily-built eval batch stream for one split (bounded by the
/// optional batch limit).
pub type EvalBatches<'a> =
    Box<dyn Fn(Option<usize>) -> Box<dyn Iterator<Item = Result<Option<Padded>>> + 'a> + 'a>;

/// The data side of one run — a train provider plus eval streams —
/// letting one epoch loop serve seed-rooted tasks (classification,
/// regression) and pair-rooted link prediction alike.
pub struct RunData<'a> {
    pub provider: Arc<dyn DatasetProvider>,
    pub batch_size: usize,
    pub pad: PadSpec,
    /// Examples per train/val/test split, for the verbose banner.
    pub split_sizes: [usize; 3],
    /// Task kind (`root_classification` | `graph_regression` |
    /// `link_prediction`) — names the journal's eval metrics.
    pub task_kind: String,
    /// Resolved hyper-parameters, for the journal header.
    pub hp: Hyperparams,
    pub val: EvalBatches<'a>,
    pub test: EvalBatches<'a>,
}

/// [`run_data_loop`] over the standard seed-rooted MAG environment —
/// the epoch loop both the AOT path and the native root tasks share.
pub fn run_loop(
    cfg: &RunConfig,
    env: &MagEnv,
    engine: &mut dyn TrainEngine,
    param_count: usize,
) -> Result<RunReport> {
    let train_seeds = env.dataset.papers_in_split(Split::Train);
    let val_seeds = env.dataset.papers_in_split(Split::Validation);
    let test_seeds = env.dataset.papers_in_split(Split::Test);
    let provider = Arc::new(SamplingProvider {
        sampler: Arc::clone(&env.sampler),
        seeds: train_seeds.clone(),
        shuffle_seed: cfg.shuffle_seed,
        sampling: SamplerConfig::with_threads(cfg.sampler_threads),
    });
    let task_kind = env
        .manifest
        .config
        .opt("task")
        .and_then(|t| t.opt("type"))
        .and_then(|v| v.as_str().ok())
        .unwrap_or("root_classification")
        .to_string();
    let data = RunData {
        provider,
        batch_size: env.batch_size,
        pad: env.pad.clone(),
        split_sizes: [train_seeds.len(), val_seeds.len(), test_seeds.len()],
        task_kind,
        hp: header_hyperparams(cfg, &env.manifest),
        val: Box::new(move |limit| Box::new(env.eval_batches(&val_seeds, limit))),
        test: Box::new(move |limit| Box::new(env.eval_batches(&test_seeds, limit))),
    };
    run_data_loop(cfg, data, engine, param_count)
}

/// The engine- and task-agnostic epoch loop: pipeline-fed train epochs
/// with per-epoch validation, a final test pass and an optional
/// checkpoint.
pub fn run_data_loop(
    cfg: &RunConfig,
    data: RunData<'_>,
    engine: &mut dyn TrainEngine,
    param_count: usize,
) -> Result<RunReport> {
    if cfg.verbose {
        println!(
            "runner: arch={} engine={:?} params={} train/val/test = {}/{}/{} examples",
            cfg.arch,
            cfg.engine,
            param_count,
            data.split_sizes[0],
            data.split_sizes[1],
            data.split_sizes[2]
        );
    }

    let mut pipe_cfg = PipelineConfig::new(data.batch_size, data.pad.clone());
    pipe_cfg.shuffle_buffer = 4 * data.batch_size;
    pipe_cfg.shuffle_seed = cfg.shuffle_seed;
    pipe_cfg.prep_threads = cfg.prep_threads;

    // Telemetry: the journal is written here — one writer, outside the
    // math — while the engine gets a handle only so a gradient-health
    // sentinel can embed the recent tail into its incident dump.
    let journal = match &cfg.events_out {
        Some(path) => Some(Arc::new(EventJournal::create(path)?)),
        None => None,
    };
    let flight = match &cfg.incident_dir {
        Some(dir) => Some(Arc::new(FlightRecorder::new(dir)?)),
        None => None,
    };
    let telemetry = Telemetry {
        grad_stats: journal.is_some(),
        grad_norm_limit: cfg.grad_norm_limit,
        flight,
        journal: journal.clone(),
    };
    if telemetry.probes_on() || telemetry.flight.is_some() {
        engine.set_telemetry(telemetry);
    }
    if let Some(j) = &journal {
        let header = RunStart {
            arch: cfg.arch.clone(),
            engine: format!("{:?}", cfg.engine).to_lowercase(),
            task: data.task_kind.clone(),
            trainer_threads: cfg.trainer_threads,
            param_count,
            epochs: cfg.epochs,
            learning_rate: data.hp.learning_rate as f64,
            dropout: data.hp.dropout as f64,
            weight_decay: data.hp.weight_decay as f64,
            grad_norm_limit: cfg.grad_norm_limit,
        };
        j.write(&header.to_event())?;
    }

    let mut epochs = Vec::new();
    let mut best_val_acc = 0.0f64;
    let mut total_steps = 0u64;
    let mut total_step_secs = 0.0f64;
    for epoch in 0..cfg.epochs {
        let _span = crate::span!("runner/epoch", epoch = epoch);
        let t0 = Instant::now();
        let stream = epoch_stream(Arc::clone(&data.provider), pipe_cfg.clone(), epoch as u64)?;
        let mut train_metrics = EpochMetrics::default();
        let mut batches = stream.iter();
        loop {
            // Time the wait on the sampler/pipeline separately from
            // the step itself — the journal's `data_wait_secs`.
            let tw = Instant::now();
            let Some(padded) = batches.next() else { break };
            let data_wait_secs = tw.elapsed().as_secs_f64();
            if crate::obs::recording() {
                crate::obs_histogram!(metric_names::TRAINER_DATA_WAIT_SECONDS)
                    .record(data_wait_secs);
            }
            let ts = Instant::now();
            let m = engine.train_batch(&padded)?;
            let step_secs = ts.elapsed().as_secs_f64();
            total_step_secs += step_secs;
            let step = total_steps;
            total_steps += 1;
            if let Some(j) = &journal {
                let grad = engine.take_grad_stats();
                let ev = StepEvent {
                    step,
                    epoch,
                    split: "train",
                    loss: m.loss as f64,
                    examples: m.weight as f64,
                    task: &m.task,
                    step_secs,
                    data_wait_secs,
                    grad: grad.as_ref(),
                };
                j.write(&ev.to_event())?;
            }
            train_metrics.add(m);
            if let Some(max) = cfg.max_steps_per_epoch {
                if train_metrics.steps >= max {
                    break;
                }
            }
        }
        drop(batches);
        let skipped =
            stream.stats.batches_skipped.load(std::sync::atomic::Ordering::Relaxed);
        drop(stream);

        let mut val_metrics = EpochMetrics::default();
        for padded in (data.val)(cfg.max_eval_batches) {
            if let Some(p) = padded? {
                val_metrics.add(engine.eval_batch(&p)?);
            }
        }
        if let Some(j) = &journal {
            let m = crate::tasks::summary_metrics(&data.task_kind, &val_metrics);
            let examples = val_metrics.examples() as f64;
            j.write(&events::eval_event(epoch, "val", val_metrics.loss(), examples, &m))?;
        }
        best_val_acc = best_val_acc.max(val_metrics.accuracy());
        let report = EpochReport {
            epoch,
            train: train_metrics,
            val: val_metrics,
            skipped_batches: skipped,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            println!(
                "epoch {:>2}: train {} | val {} | skipped {} | {:.1}s",
                epoch, report.train, report.val, skipped, report.wall_secs
            );
        }
        epochs.push(report);
    }

    let mut test = EpochMetrics::default();
    for padded in (data.test)(cfg.max_eval_batches) {
        if let Some(p) = padded? {
            test.add(engine.eval_batch(&p)?);
        }
    }
    if cfg.verbose {
        println!("test: {test}");
    }
    if let Some(j) = &journal {
        let last_epoch = cfg.epochs.saturating_sub(1);
        let m = crate::tasks::summary_metrics(&data.task_kind, &test);
        j.write(&events::eval_event(last_epoch, "test", test.loss(), test.examples() as f64, &m))?;
        j.write(&events::run_end_event(total_steps, total_step_secs, best_val_acc))?;
    }

    if let Some(path) = &cfg.checkpoint {
        engine.write_checkpoint(path)?;
        if cfg.verbose {
            println!("checkpoint written to {}", path.display());
        }
    }

    if epochs.is_empty() {
        return Err(Error::Pipeline("0 epochs requested".into()));
    }
    Ok(RunReport {
        arch: cfg.arch.clone(),
        param_count,
        epochs,
        best_val_acc,
        test,
        train_steps_per_sec: if total_step_secs > 0.0 {
            total_steps as f64 / total_step_secs
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("aot").unwrap(), EngineKind::Aot);
        assert_eq!(EngineKind::parse("native").unwrap(), EngineKind::Native);
        assert!(EngineKind::parse("tpu").is_err());
        assert_eq!(EngineKind::default(), EngineKind::Aot);
    }

    /// A scaled-down run config so runner tests stay fast: the tiny
    /// synth MAG with the mag_small schema/sampling/pad contract,
    /// parameterized over extra model-block keys (`"type"` etc. —
    /// spliced in front of `hidden_dim`, so pass e.g.
    /// `r#""type": "gatv2", "att_dim": 4,"#` or `""`).
    fn tiny_config_text(model_extra: &str) -> String {
        let base = r#"{
          "batch_size": 4,
          "dataset": {
            "num_papers": 120, "num_authors": 150, "num_institutions": 10,
            "num_fields": 12, "num_classes": 4, "num_communities": 4,
            "feature_dim": 16, "mean_citations": 4.0,
            "mean_authors_per_paper": 2.0, "mean_topics": 1.5,
            "community_coherence": 0.85, "label_coherence": 0.75,
            "feature_noise": 0.8, "year_min": 2010, "year_max": 2019,
            "seed": 17
          },
          "schema": {
            "node_sets": {
              "paper": {"features": {"feat": 16}},
              "author": {},
              "institution": {"id_embedding": true, "cardinality": 10},
              "field_of_study": {"id_embedding": true, "cardinality": 12}
            },
            "edge_sets": {
              "cites": ["paper", "paper"],
              "written": ["paper", "author"],
              "writes": ["author", "paper"],
              "affiliated_with": ["author", "institution"],
              "has_topic": ["paper", "field_of_study"]
            }
          },
          "sampling": {
            "plan_seed": 42,
            "sizes": {"cites": 3, "written": 2, "writes": 2,
                      "affiliated_with": 2, "has_topic": 2}
          },
          "pad": {
            "node_caps": {"paper": 128, "author": 80, "institution": 48,
                          "field_of_study": 56},
            "edge_caps": {"cites": 16, "written": 40, "writes": 80,
                          "affiliated_with": 80, "has_topic": 192},
            "component_cap": 5
          },
          "model": {
            "hidden_dim": 8, "message_dim": 8, "num_layers": 1,
            "updates": {"paper": ["cites", "written", "has_topic"],
                        "author": ["writes", "affiliated_with"]}
          },
          "train": {
            "num_classes": 4, "init_seed": 3, "learning_rate": 0.01,
            "weight_decay": 0.0001, "adam_beta1": 0.9,
            "adam_beta2": 0.999, "adam_eps": 1e-8
          }
        }"#;
        base.replace("\"hidden_dim\": 8,", &format!("{model_extra} \"hidden_dim\": 8,"))
    }

    /// The native engine runs the full runner loop — pipeline, epochs,
    /// validation, test, checkpoint — straight from a raw config file,
    /// with zero AOT artifacts.
    #[test]
    fn native_run_from_config_file_end_to_end() {
        let text = tiny_config_text("");
        let dir = std::env::temp_dir().join(format!("tfgnn-run-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("tiny.json");
        std::fs::write(&cfg_path, text).unwrap();
        let ckpt_path = dir.join("native.ckpt");

        let mut cfg = RunConfig::new(&dir, "mpnn");
        cfg.engine = EngineKind::Native;
        cfg.config_path = Some(cfg_path);
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(4);
        cfg.max_eval_batches = Some(2);
        cfg.trainer_threads = 2;
        cfg.checkpoint = Some(ckpt_path.clone());
        let report = run(&cfg).unwrap();
        assert_eq!(report.epochs.len(), 1);
        assert!(report.param_count > 0);
        assert!(report.epochs[0].train.steps > 0, "pipeline fed the native engine");
        assert!(report.epochs[0].train.loss().is_finite());
        assert!(report.train_steps_per_sec > 0.0);
        // The checkpoint carries full native state (params + moments +
        // step), restorable by the codec.
        let tensors = crate::train::checkpoint::load(&ckpt_path).unwrap();
        assert!(tensors.iter().any(|(n, _)| n == "step"));
        assert!(tensors.iter().any(|(n, _)| n.starts_with("adam_m.")));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// `--events-out` writes a parseable `tfgnn_events_v1` journal:
    /// run_start header, one step record per optimizer step carrying
    /// the gradient probe fields, eval records for val + test, and a
    /// run_end trailer.
    #[test]
    fn native_run_writes_event_journal() {
        let text = tiny_config_text("");
        let dir = std::env::temp_dir().join(format!("tfgnn-run-ev-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("tiny.json");
        std::fs::write(&cfg_path, text).unwrap();
        let events_path = dir.join("events.jsonl");
        let mut cfg = RunConfig::new(&dir, "mpnn");
        cfg.engine = EngineKind::Native;
        cfg.config_path = Some(cfg_path);
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(3);
        cfg.max_eval_batches = Some(1);
        cfg.trainer_threads = 2;
        cfg.events_out = Some(events_path.clone());
        let report = run(&cfg).unwrap();
        let s = crate::obs::events::RunSummary::from_path(&events_path).unwrap();
        assert_eq!(s.steps, report.epochs[0].train.steps as u64);
        assert!(s.final_train_loss().is_some());
        assert!(s.final_eval("val").is_some());
        assert!(s.final_eval("test").is_some());
        assert!(s.end.is_some());
        let raw = std::fs::read_to_string(&events_path).unwrap();
        assert!(raw.contains("\"grad_norm\""), "step records carry probe fields: {raw}");
        assert!(raw.contains("\"update_ratio\""), "{raw}");
        assert!(raw.contains("\"data_wait_secs\""), "{raw}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A `task` block selects graph regression through the same runner
    /// loop: the epoch metrics report MSE/MAE and the checkpoint
    /// carries the regression head instead of the classifier.
    #[test]
    fn native_run_graph_regression_from_config() {
        let text = tiny_config_text("").replace(
            "\"train\": {",
            r#""task": {"type": "graph_regression", "target_feature": "year",
                        "target_shift": 2010.0, "target_scale": 0.1},
               "train": {"#,
        );
        let dir =
            std::env::temp_dir().join(format!("tfgnn-run-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("reg.json");
        std::fs::write(&cfg_path, text).unwrap();
        let ckpt_path = dir.join("reg.ckpt");
        let mut cfg = RunConfig::new(&dir, "mpnn");
        cfg.engine = EngineKind::Native;
        cfg.config_path = Some(cfg_path);
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(3);
        cfg.max_eval_batches = Some(2);
        cfg.trainer_threads = 2;
        cfg.checkpoint = Some(ckpt_path.clone());
        let report = run(&cfg).unwrap();
        assert!(report.epochs[0].train.steps > 0);
        assert!(report.epochs[0].train.loss().is_finite());
        assert!(report.epochs[0].train.mse() > 0.0, "regression reported MSE");
        assert!(report.test.mae().is_finite());
        let tensors = crate::train::checkpoint::load(&ckpt_path).unwrap();
        assert!(tensors.iter().any(|(n, _)| n == "param.reg.w"));
        assert!(tensors.iter().all(|(n, _)| n != "param.head.w"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A link-prediction `task` block reroutes the whole run: edge
    /// holdout, pair subgraph pipeline, MRR/hits@k eval, and a
    /// checkpoint carrying the Hadamard-MLP head.
    #[test]
    fn native_run_link_prediction_from_config() {
        // Pair examples merge 1 + 1 + negatives rooted expansions, so
        // the caps scale up and the batch shrinks vs the seed-rooted
        // config.
        let text = tiny_config_text("")
            .replace("\"batch_size\": 4,", "\"batch_size\": 2,")
            .replace(
                r#""node_caps": {"paper": 128, "author": 80, "institution": 48,"#,
                r#""node_caps": {"paper": 256, "author": 160, "institution": 96,"#,
            )
            .replace(r#""field_of_study": 56},"#, r#""field_of_study": 112},"#)
            .replace(
                r#""edge_caps": {"cites": 16, "written": 40, "writes": 80,"#,
                r#""edge_caps": {"cites": 48, "written": 96, "writes": 192,"#,
            )
            .replace(
                r#""affiliated_with": 80, "has_topic": 192},"#,
                r#""affiliated_with": 192, "has_topic": 448},"#,
            )
            .replace("\"component_cap\": 5", "\"component_cap\": 3")
            .replace(
                "\"train\": {",
                r#""task": {"type": "link_prediction", "edge_set": "cites",
                            "readout": "hadamard", "mlp_dim": 8,
                            "negatives": 2, "hits_k": 2,
                            "holdout_fraction": 0.3, "split_seed": 9},
                   "train": {"#,
            );
        let dir =
            std::env::temp_dir().join(format!("tfgnn-run-lp-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("lp.json");
        std::fs::write(&cfg_path, text).unwrap();
        let ckpt_path = dir.join("lp.ckpt");
        let mut cfg = RunConfig::new(&dir, "mpnn");
        cfg.engine = EngineKind::Native;
        cfg.config_path = Some(cfg_path);
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(4);
        cfg.max_eval_batches = Some(3);
        cfg.trainer_threads = 2;
        cfg.checkpoint = Some(ckpt_path.clone());
        let report = run(&cfg).unwrap();
        assert!(report.epochs[0].train.steps > 0, "pair pipeline fed the trainer");
        assert!(report.epochs[0].train.loss().is_finite());
        assert!(report.epochs[0].train.mrr() > 0.0, "MRR reported on train");
        let val = &report.epochs[0].val;
        if val.task.scored > 0.0 {
            assert!(val.mrr() > 0.0 && val.mrr() <= 1.0, "val MRR in (0,1]: {}", val.mrr());
            assert!(val.hits_at_k() <= 1.0);
        }
        let tensors = crate::train::checkpoint::load(&ckpt_path).unwrap();
        assert!(tensors.iter().any(|(n, _)| n == "param.lp.w"), "Hadamard head saved");
        assert!(tensors.iter().all(|(n, _)| n != "param.head.w"), "no classifier head");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// `tfgnn train --engine native --config` picks the model from the
    /// config's `model.type`: every convolution of the zoo trains
    /// through the same runner loop, and the checkpoint carries the
    /// architecture's own parameter names.
    #[test]
    fn native_run_picks_model_type_from_config() {
        let dir =
            std::env::temp_dir().join(format!("tfgnn-run-zoo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (arch, extra, marker) in [
            ("gcn", r#""type": "gcn","#, "param.l0.paper.cites.gcn.w"),
            ("sage", r#""type": "sage", "sage_reduce": "max","#, "param.l0.paper.cites.sage.w"),
            ("gatv2", r#""type": "gatv2", "att_dim": 4,"#, "param.l0.paper.cites.att.v"),
        ] {
            let cfg_path = dir.join(format!("{arch}.json"));
            std::fs::write(&cfg_path, tiny_config_text(extra)).unwrap();
            let ckpt_path = dir.join(format!("{arch}.ckpt"));
            let mut cfg = RunConfig::new(&dir, arch);
            cfg.engine = EngineKind::Native;
            cfg.config_path = Some(cfg_path);
            cfg.epochs = 1;
            cfg.max_steps_per_epoch = Some(2);
            cfg.max_eval_batches = Some(1);
            cfg.trainer_threads = 2;
            cfg.checkpoint = Some(ckpt_path.clone());
            let report = run(&cfg).unwrap_or_else(|e| panic!("{arch}: {e}"));
            assert!(report.epochs[0].train.steps > 0, "{arch}");
            assert!(report.epochs[0].train.loss().is_finite(), "{arch}");
            let tensors = crate::train::checkpoint::load(&ckpt_path).unwrap();
            assert!(
                tensors.iter().any(|(n, _)| n == marker),
                "{arch}: checkpoint missing {marker}"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
