//! Parameter checkpoints (the SavedModel stand-in, §6.2.2 / §6.3).
//!
//! Format: magic `TFGC`, then per tensor: name, dtype tag, shape,
//! raw little-endian data, followed by a trailing FNV checksum of the
//! whole payload. Restorable by [`load`] and consumed by the serving
//! path as its "exported model".

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::HostTensor;
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"TFGC";

/// Fixed-size copy of an exact-length chunk. Callers slice exactly `N`
/// bytes (`take` / `chunks_exact`), so no fallible `try_into` is
/// needed.
fn arr<const N: usize>(c: &[u8]) -> [u8; N] {
    let mut a = [0u8; N];
    a.copy_from_slice(c);
    a
}

fn fnv(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save named tensors to a checkpoint file.
pub fn save(path: &Path, params: &[(String, HostTensor)]) -> Result<()> {
    let mut payload = Vec::new();
    payload.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for (name, t) in params {
        payload.extend_from_slice(&(name.len() as u64).to_le_bytes());
        payload.extend_from_slice(name.as_bytes());
        let shape = t.shape();
        payload.push(match t {
            HostTensor::F32(..) => 0,
            HostTensor::I32(..) => 1,
            HostTensor::I64(..) => 2,
        });
        payload.extend_from_slice(&(shape.len() as u64).to_le_bytes());
        for &d in shape {
            payload.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match t {
            HostTensor::F32(_, v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            HostTensor::I32(_, v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            HostTensor::I64(_, v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&payload)?;
    f.write_all(&fnv(&payload).to_le_bytes())?;
    Ok(())
}

/// Load a checkpoint file.
pub fn load(path: &Path) -> Result<Vec<(String, HostTensor)>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < 12 || &bytes[..4] != MAGIC {
        return Err(Error::Codec(format!("{}: not a checkpoint", path.display())));
    }
    let payload = &bytes[4..bytes.len() - 8];
    let want = u64::from_le_bytes(arr(&bytes[bytes.len() - 8..]));
    if fnv(payload) != want {
        return Err(Error::Codec(format!("{}: checksum mismatch", path.display())));
    }
    let mut i = 0usize;
    let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
        if *i + n > payload.len() {
            return Err(Error::Codec("checkpoint truncated".into()));
        }
        let s = &payload[*i..*i + n];
        *i += n;
        Ok(s)
    };
    let read_u64 = |i: &mut usize| -> Result<u64> { Ok(u64::from_le_bytes(arr(take(i, 8)?))) };
    let count = read_u64(&mut i)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u64(&mut i)? as usize;
        let name = String::from_utf8(take(&mut i, name_len)?.to_vec())
            .map_err(|_| Error::Codec("bad name".into()))?;
        let tag = take(&mut i, 1)?[0];
        let rank = read_u64(&mut i)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut i)? as usize);
        }
        // NB: the empty product is 1, so rank-0 scalars come out right
        // without a `.max(1)` — which would mis-read genuinely empty
        // tensors (a shape containing 0) by consuming one phantom
        // element and corrupting every slot after it.
        let elems = shape.iter().product::<usize>();
        let t = match tag {
            0 => {
                let raw = take(&mut i, elems * 4)?;
                HostTensor::F32(
                    shape,
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(arr(c))).collect(),
                )
            }
            1 => {
                let raw = take(&mut i, elems * 4)?;
                HostTensor::I32(
                    shape,
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(arr(c))).collect(),
                )
            }
            2 => {
                let raw = take(&mut i, elems * 8)?;
                HostTensor::I64(
                    shape,
                    raw.chunks_exact(8).map(|c| i64::from_le_bytes(arr(c))).collect(),
                )
            }
            t => return Err(Error::Codec(format!("bad dtype tag {t}"))),
        };
        out.push((name, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tfgnn-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let params = vec![
            (
                "param.w".to_string(),
                HostTensor::F32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-7, 9.9]),
            ),
            ("param.ids".to_string(), HostTensor::I32(vec![4], vec![1, -2, 3, 4])),
            ("param.big".to_string(), HostTensor::I64(vec![], vec![i64::MAX])),
        ];
        let p = tmp("rt.ckpt");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(params, back);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let params = vec![("w".to_string(), HostTensor::F32(vec![2], vec![1.0, 2.0]))];
        let p = tmp("corrupt.ckpt");
        save(&p, &params).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn not_a_checkpoint() {
        let p = tmp("junk.ckpt");
        std::fs::write(&p, b"hello world junk").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    /// A zero-size tensor (a shape containing 0) must round-trip
    /// without shifting the slots that follow it.
    #[test]
    fn zero_size_tensor_roundtrips() {
        let params = vec![
            ("empty".to_string(), HostTensor::F32(vec![0, 4], vec![])),
            ("after".to_string(), HostTensor::F32(vec![2], vec![7.0, 8.0])),
        ];
        let p = tmp("empty.ckpt");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(params, back);
        std::fs::remove_file(&p).unwrap();
    }

    /// Bit-exact equality check that — unlike `PartialEq` — treats NaN
    /// payloads as equal when their bit patterns are.
    fn assert_bits_eq(a: &[(String, HostTensor)], b: &[(String, HostTensor)]) {
        assert_eq!(a.len(), b.len());
        for ((an, at), (bn, bt)) in a.iter().zip(b) {
            assert_eq!(an, bn);
            assert_eq!(at.shape(), bt.shape(), "{an}");
            match (at, bt) {
                (HostTensor::F32(_, x), HostTensor::F32(_, y)) => {
                    assert_eq!(x.len(), y.len(), "{an}");
                    for (v, w) in x.iter().zip(y) {
                        assert_eq!(v.to_bits(), w.to_bits(), "{an}");
                    }
                }
                (HostTensor::I32(_, x), HostTensor::I32(_, y)) => assert_eq!(x, y, "{an}"),
                (HostTensor::I64(_, x), HostTensor::I64(_, y)) => assert_eq!(x, y, "{an}"),
                _ => panic!("{an}: dtype changed in roundtrip"),
            }
        }
    }

    /// Property: full native-trainer state (`param.* ++ adam_m.* ++
    /// adam_v.* ++ step`) round-trips bit-exactly through the codec —
    /// including NaN/±inf/-0.0 payloads, which `assert_eq!` on floats
    /// cannot see past (NaN != NaN) but training state can legitimately
    /// contain.
    #[test]
    fn prop_native_trainer_state_roundtrips_with_nonfinite_payloads() {
        use crate::ops::model_ref::Mat;
        use crate::train::native::{state_from_tensors, state_to_tensors, Adam, AdamConfig};
        use crate::util::proptest::check;
        check("native state roundtrip incl NaN/±inf", 20, |rng| {
            let special = [
                f32::NAN,
                f32::INFINITY,
                f32::NEG_INFINITY,
                -0.0,
                0.0,
                f32::MIN_POSITIVE, // subnormal neighborhood
                3.4e38,
            ];
            let n_params = 1 + rng.uniform(4);
            let mut names = Vec::new();
            let mut params = Vec::new();
            for i in 0..n_params {
                let rows = 1 + rng.uniform(4);
                let cols = 1 + rng.uniform(5);
                let data: Vec<f32> = (0..rows * cols)
                    .map(|_| {
                        if rng.chance(0.3) {
                            special[rng.uniform(special.len())]
                        } else {
                            rng.range_f32(-5.0, 5.0)
                        }
                    })
                    .collect();
                names.push(format!("layer{i}.w"));
                params.push(Mat { rows, cols, data });
            }
            let mut adam = Adam::new(AdamConfig::default(), &params);
            adam.steps = rng.uniform(10_000) as u64;
            for m in adam.m.iter_mut().chain(adam.v.iter_mut()) {
                for v in &mut m.data {
                    *v = if rng.chance(0.2) {
                        special[rng.uniform(special.len())]
                    } else {
                        rng.range_f32(-1.0, 1.0)
                    };
                }
            }
            let tensors = state_to_tensors(&names, &params, &adam);
            let path = tmp(&format!("native-prop-{}", rng.uniform(1 << 30)));
            save(&path, &tensors).unwrap();
            let back = load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_bits_eq(&tensors, &back);
            // And the decoded state reconstructs the trainer tensors.
            let (p2, m2, v2, steps) =
                state_from_tensors(&names, &params, &back).unwrap();
            assert_eq!(steps, adam.steps);
            for (a, b) in params.iter().zip(&p2) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            for (a, b) in adam.m.iter().zip(&m2) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            for (a, b) in adam.v.iter().zip(&v2) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        });
    }
}
