//! Native training engine: reverse-mode gradients + a data-parallel
//! Rust trainer for the message-passing kernels.
//!
//! The AOT path ([`crate::train::Trainer`]) needs lowered HLO programs
//! and a PJRT runtime; this subsystem trains the same mpnn architecture
//! end-to-end in pure Rust, so the whole §6.2 story — sample → pipeline
//! → train step → checkpoint — runs offline and joins sampling in the
//! bench-smoke perf trajectory (`benches/training.rs`).
//!
//! Three layers (see DESIGN.md §Native training engine):
//! * [`grad`] — hand-written VJPs for every forward op (matmul, bias,
//!   relu, concat, gather, segment sum/mean/max, broadcast, masked
//!   softmax cross-entropy), each finite-difference checked;
//! * [`optim`] — Adam with decoupled weight decay over flat `Vec<Mat>`
//!   state, checkpoint-compatible with [`crate::train::checkpoint`];
//! * [`trainer`] — [`NativeTrainer`], sharding a padded batch's
//!   examples over [`crate::util::ThreadPool`] replicas with a
//!   deterministic in-order all-reduce; the per-example objective is a
//!   [`crate::tasks::Task`] (root classification, link prediction,
//!   graph regression), and [`train_step_oracle_task`] /
//!   [`train_step_oracle`] are the serial bit-for-bit references.
//!
//! [`model`] holds the trainable [`NativeModel`]: a generic
//! [`crate::layers::GraphUpdate`] stack whose convolution is chosen by
//! the config's `model.type` (mpnn | gcn | sage | gatv2). For the mpnn
//! configuration the forward is composed from the staged functions of
//! [`crate::ops::model_ref`] — the per-root logits are bit-for-bit
//! those of the AOT bit-level reference over the padded batch.

pub mod grad;
pub mod model;
pub mod optim;
pub mod trainer;

pub use model::{NativeModel, Tape, TrunkTape};
pub use optim::{state_from_tensors, state_to_tensors, Adam, AdamConfig};
pub use trainer::{train_step_oracle, train_step_oracle_task, NativeTrainer};
