//! Reverse-mode gradients for the message-passing kernel vocabulary.
//!
//! Hand-written vector-Jacobian products (VJPs) for every op the mpnn
//! reference forward is built from: matmul, bias add, relu, column
//! concat, row gather, segment sum/mean/max pooling, node→edge
//! broadcast, and masked softmax cross-entropy. Each rule is validated
//! against central finite differences in this module's tests (rel err
//! ≤ 1e-3 at f32, h = 1e-2 — see DESIGN.md §Native training engine for
//! how the tolerance was chosen), across multiple shapes including
//! empty segments, zero-row inputs and masked-out roots.
//!
//! Conventions: `d<x>` is ∂L/∂x with the same shape as `x`; all rules
//! are pure functions so the model backward composes them explicitly
//! (the "tape" is the set of saved forward activations, not a graph of
//! closures).

use crate::ops::model_ref::Mat;

/// VJP of `c = a @ w`: returns `(da, dw) = (dc @ wᵀ, aᵀ @ dc)`.
pub fn matmul_vjp(a: &Mat, w: &Mat, dc: &Mat) -> (Mat, Mat) {
    assert_eq!(dc.rows, a.rows, "matmul_vjp: dc rows");
    assert_eq!(dc.cols, w.cols, "matmul_vjp: dc cols");
    (dc.matmul(&w.transpose()), a.transpose().matmul(dc))
}

/// VJP of `z = x + b` (bias broadcast over rows): `db` = column sums.
pub fn bias_vjp(dz: &Mat) -> Vec<f32> {
    dz.col_sums()
}

/// VJP of `h = relu(z)`: pass the gradient where the forward passed the
/// value. The forward (`Mat::relu`) zeroes `v < 0.0` and keeps `v >= 0`
/// (including ±0), so the subgradient at exactly 0 is 1 — matched here.
pub fn relu_vjp(z: &Mat, dh: &Mat) -> Mat {
    assert_eq!(z.rows, dh.rows, "relu_vjp: rows");
    assert_eq!(z.cols, dh.cols, "relu_vjp: cols");
    let mut out = dh.clone();
    for (o, &zv) in out.data.iter_mut().zip(&z.data) {
        if zv < 0.0 {
            *o = 0.0;
        }
    }
    out
}

/// VJP of `c = concat_cols(parts)`: split `dc` back into the parts'
/// column ranges. `widths` are the parts' column counts, in order.
pub fn concat_cols_vjp(widths: &[usize], dc: &Mat) -> Vec<Mat> {
    assert_eq!(widths.iter().sum::<usize>(), dc.cols, "concat_cols_vjp: widths");
    let mut out: Vec<Mat> = widths.iter().map(|&w| Mat::zeros(dc.rows, w)).collect();
    for r in 0..dc.rows {
        let mut at = 0;
        for (p, &w) in out.iter_mut().zip(widths) {
            p.data[r * w..(r + 1) * w].copy_from_slice(&dc.row(r)[at..at + w]);
            at += w;
        }
    }
    out
}

/// VJP of `y = x.gather(idx)`: scatter-add the output rows back onto
/// the `n_src` source rows (rows gathered k times receive k gradient
/// contributions).
pub fn gather_vjp(idx: &[i32], n_src: usize, dy: &Mat) -> Mat {
    assert_eq!(idx.len(), dy.rows, "gather_vjp: rows");
    let mut out = Mat::zeros(n_src, dy.cols);
    for (r, &i) in idx.iter().enumerate() {
        let dst = &mut out.data[i as usize * dy.cols..(i as usize + 1) * dy.cols];
        for (o, &v) in dst.iter_mut().zip(dy.row(r)) {
            *o += v;
        }
    }
    out
}

/// VJP of `y = x.segment_sum(seg, n)`: every contributing row receives
/// its segment's gradient row — a gather.
pub fn segment_sum_vjp(seg: &[i32], dy: &Mat) -> Mat {
    dy.gather(seg)
}

/// Forward: mean per segment over Mat rows (empty segments yield 0),
/// matching [`crate::ops::segment_mean`]'s numerics (sum, then scale by
/// `1.0 / count`).
pub fn segment_mean_fwd(x: &Mat, seg: &[i32], n_seg: usize) -> Mat {
    assert_eq!(x.rows, seg.len(), "segment_mean_fwd: rows");
    let segs: Vec<u32> = seg.iter().map(|&s| s as u32).collect();
    let data = crate::ops::segment_mean(&x.data, &segs, n_seg, x.cols);
    Mat { rows: n_seg, cols: x.cols, data }
}

/// VJP of [`segment_mean_fwd`]: `dx[r] = dy[seg[r]] / count[seg[r]]`,
/// using the same `1.0 / count` factor as the forward.
pub fn segment_mean_vjp(seg: &[i32], n_seg: usize, dy: &Mat) -> Mat {
    let mut counts = vec![0u32; n_seg];
    for &s in seg {
        counts[s as usize] += 1;
    }
    let inv: Vec<f32> =
        counts.iter().map(|&c| if c > 0 { 1.0 / c as f32 } else { 0.0 }).collect();
    let mut out = Mat::zeros(seg.len(), dy.cols);
    for (r, &s) in seg.iter().enumerate() {
        let f = inv[s as usize];
        let src = dy.row(s as usize);
        let dst = &mut out.data[r * dy.cols..(r + 1) * dy.cols];
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = v * f;
        }
    }
    out
}

/// Forward: max per segment (empty segments clamped to 0, exactly like
/// [`crate::ops::segment_max`]), additionally returning the winning row
/// per `(segment, column)` — `-1` for empty segments — which is the
/// tape entry [`segment_max_vjp`] routes gradients along.
pub fn segment_max_fwd(x: &Mat, seg: &[i32], n_seg: usize) -> (Mat, Vec<i32>) {
    assert_eq!(x.rows, seg.len(), "segment_max_fwd: rows");
    let d = x.cols;
    let mut out = Mat { rows: n_seg, cols: d, data: vec![f32::NEG_INFINITY; n_seg * d] };
    let mut argmax = vec![-1i32; n_seg * d];
    let mut counts = vec![0u32; n_seg];
    for (i, &s) in seg.iter().enumerate() {
        let s = s as usize;
        counts[s] += 1;
        for k in 0..d {
            let v = x.data[i * d + k];
            let o = &mut out.data[s * d + k];
            // NaN is sticky, ties keep the first occurrence — the same
            // update rule as ops::segment_max.
            if v.is_nan() || (!o.is_nan() && v > *o) {
                *o = v;
                argmax[s * d + k] = i as i32;
            }
        }
    }
    for (s, &c) in counts.iter().enumerate() {
        if c == 0 {
            for k in 0..d {
                out.data[s * d + k] = 0.0;
            }
        }
    }
    (out, argmax)
}

/// VJP of [`segment_max_fwd`]: route each `(segment, column)` gradient
/// to the row that won the max (the standard subgradient; empty
/// segments contribute nothing).
pub fn segment_max_vjp(argmax: &[i32], n_rows: usize, dy: &Mat) -> Mat {
    assert_eq!(argmax.len(), dy.rows * dy.cols, "segment_max_vjp: argmax len");
    let d = dy.cols;
    let mut out = Mat::zeros(n_rows, d);
    for s in 0..dy.rows {
        for k in 0..d {
            let i = argmax[s * d + k];
            if i >= 0 {
                out.data[i as usize * d + k] += dy.data[s * d + k];
            }
        }
    }
    out
}

/// Forward: broadcast per-segment rows onto items (node→edge
/// broadcast): `y[r] = values[seg[r]]` — a gather by segment id.
pub fn broadcast_fwd(values: &Mat, seg: &[i32]) -> Mat {
    values.gather(seg)
}

/// VJP of [`broadcast_fwd`]: sum item gradients back per segment.
pub fn broadcast_vjp(seg: &[i32], n_src: usize, dy: &Mat) -> Mat {
    dy.segment_sum(seg, n_src)
}

/// Forward: per-segment softmax of one scalar `logit` per row, then a
/// softmax-weighted sum of `vals` rows into `n_seg` segments — the
/// attention aggregation of
/// [`crate::ops::softmax_weighted_pool_fused`], phrased over per-edge
/// (already gathered) value rows so it can sit on a tape.
///
/// Bit-for-bit contract with the fused kernel: rows are grouped by the
/// same stable counting sort the CSR view uses (edge ids ascending
/// within each segment), the per-segment max / normalizer / weighted
/// accumulation all fold in that order, and each weight is computed as
/// `exp(l - max) / sum` exactly like `softmax_pool_rows`. Asserted by
/// a property test in [`crate::layers`]. Empty segments yield zero
/// rows; returns `(out, weights)` with one softmax weight per input
/// row — the tape entry [`segment_softmax_pool_vjp`] consumes.
pub fn segment_softmax_pool_fwd(
    logits: &[f32],
    vals: &Mat,
    seg: &[i32],
    n_seg: usize,
) -> (Mat, Vec<f32>) {
    assert_eq!(logits.len(), seg.len(), "segment_softmax_pool_fwd: logits len");
    assert_eq!(vals.rows, seg.len(), "segment_softmax_pool_fwd: vals rows");
    let d = vals.cols;
    // Stable counting sort over row ids — the CSR build's grouping.
    let mut offsets = vec![0usize; n_seg + 1];
    for &s in seg {
        offsets[s as usize + 1] += 1;
    }
    for i in 1..offsets.len() {
        offsets[i] += offsets[i - 1];
    }
    let mut cursor = offsets.clone();
    let mut order = vec![0u32; seg.len()];
    for (e, &s) in seg.iter().enumerate() {
        let at = cursor[s as usize];
        order[at] = e as u32;
        cursor[s as usize] = at + 1;
    }
    let mut out = Mat::zeros(n_seg, d);
    let mut weights = vec![0.0f32; seg.len()];
    for r in 0..n_seg {
        let row = &order[offsets[r]..offsets[r + 1]];
        if row.is_empty() {
            continue; // empty segments stay 0 (padded-graph rule)
        }
        let mut m = f32::NEG_INFINITY;
        for &e in row {
            let l = logits[e as usize];
            if l > m {
                m = l;
            }
        }
        let mut sum = 0.0f32;
        for &e in row {
            let x = (logits[e as usize] - m).exp();
            weights[e as usize] = x;
            sum += x;
        }
        let acc = &mut out.data[r * d..(r + 1) * d];
        for &e in row {
            let w = weights[e as usize] / sum;
            weights[e as usize] = w;
            let src = vals.row(e as usize);
            for (o, &x) in acc.iter_mut().zip(src) {
                *o += w * x;
            }
        }
    }
    (out, weights)
}

/// VJP of [`segment_softmax_pool_fwd`]: given `dy = ∂L/∂out` and the
/// saved softmax `weights`, returns `(dlogits, dvals)`.
///
/// With `w_e = softmax(l)_e` within segment `r` and
/// `out_r = Σ_e w_e · v_e`:
/// * `dv_e = w_e · dy_r`;
/// * `dl_e = w_e · (g_e - ḡ_r)` where `g_e = ⟨v_e, dy_r⟩` and
///   `ḡ_r = Σ_e w_e g_e` — the standard softmax Jacobian contracted
///   with the per-row value gradients.
pub fn segment_softmax_pool_vjp(
    weights: &[f32],
    vals: &Mat,
    seg: &[i32],
    dy: &Mat,
) -> (Vec<f32>, Mat) {
    assert_eq!(weights.len(), seg.len(), "segment_softmax_pool_vjp: weights len");
    assert_eq!(vals.rows, seg.len(), "segment_softmax_pool_vjp: vals rows");
    assert_eq!(vals.cols, dy.cols, "segment_softmax_pool_vjp: cols");
    let d = vals.cols;
    let mut dvals = Mat::zeros(vals.rows, d);
    let mut gs = vec![0.0f32; seg.len()];
    let mut gbar = vec![0.0f32; dy.rows];
    for (e, &s) in seg.iter().enumerate() {
        let r = s as usize;
        let dyr = dy.row(r);
        let w = weights[e];
        let dst = &mut dvals.data[e * d..(e + 1) * d];
        let mut g = 0.0f32;
        for ((o, &dv), &v) in dst.iter_mut().zip(dyr).zip(vals.row(e)) {
            *o = w * dv;
            g += v * dv;
        }
        gs[e] = g;
        gbar[r] += w * g;
    }
    let dlogits = seg
        .iter()
        .enumerate()
        .map(|(e, &s)| weights[e] * (gs[e] - gbar[s as usize]))
        .collect();
    (dlogits, dvals)
}

/// Forward: per-row dot product of two `[n, d]` matrices —
/// `s_i = ⟨a_i, b_i⟩`. The parameter-free pair scorer of the
/// link-prediction readout (one row per candidate pair).
pub fn row_dot_fwd(a: &Mat, b: &Mat) -> Vec<f32> {
    assert_eq!(a.rows, b.rows, "row_dot_fwd: rows");
    assert_eq!(a.cols, b.cols, "row_dot_fwd: cols");
    (0..a.rows)
        .map(|r| a.row(r).iter().zip(b.row(r)).map(|(&x, &y)| x * y).sum())
        .collect()
}

/// VJP of [`row_dot_fwd`]: `da_i = ds_i · b_i`, `db_i = ds_i · a_i`.
pub fn row_dot_vjp(a: &Mat, b: &Mat, ds: &[f32]) -> (Mat, Mat) {
    assert_eq!(ds.len(), a.rows, "row_dot_vjp: ds len");
    let mut da = Mat::zeros(a.rows, a.cols);
    let mut db = Mat::zeros(b.rows, b.cols);
    for (r, &d) in ds.iter().enumerate() {
        let (ar, br) = (a.row(r), b.row(r));
        let dst_a = &mut da.data[r * a.cols..(r + 1) * a.cols];
        for (o, &y) in dst_a.iter_mut().zip(br) {
            *o = d * y;
        }
        let dst_b = &mut db.data[r * b.cols..(r + 1) * b.cols];
        for (o, &x) in dst_b.iter_mut().zip(ar) {
            *o = d * x;
        }
    }
    (da, db)
}

/// Forward: element-wise (Hadamard) product `y = a ∘ b` — the input of
/// the link-prediction MLP scorer.
pub fn hadamard_fwd(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "hadamard_fwd: rows");
    assert_eq!(a.cols, b.cols, "hadamard_fwd: cols");
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| x * y).collect();
    Mat { rows: a.rows, cols: a.cols, data }
}

/// VJP of [`hadamard_fwd`]: `da = dy ∘ b`, `db = dy ∘ a`.
pub fn hadamard_vjp(a: &Mat, b: &Mat, dy: &Mat) -> (Mat, Mat) {
    assert_eq!(dy.rows, a.rows, "hadamard_vjp: rows");
    assert_eq!(dy.cols, a.cols, "hadamard_vjp: cols");
    let da = Mat {
        rows: a.rows,
        cols: a.cols,
        data: dy.data.iter().zip(&b.data).map(|(&d, &y)| d * y).collect(),
    };
    let db = Mat {
        rows: a.rows,
        cols: a.cols,
        data: dy.data.iter().zip(&a.data).map(|(&d, &x)| d * x).collect(),
    };
    (da, db)
}

/// Margin ranking loss over candidate scores: `scores[0]` is the
/// positive, the rest negatives;
/// `L = Σ_{i≥1} max(0, margin − s_0 + s_i)`. Returns `(L, ∂L/∂s)` —
/// the subgradient at an exactly-active hinge counts as active,
/// matching relu's `v >= 0` convention. A candidate list with no
/// negatives yields zero loss and gradients.
pub fn margin_rank(scores: &[f32], margin: f32) -> (f32, Vec<f32>) {
    assert!(!scores.is_empty(), "margin_rank: no scores");
    let s0 = scores[0];
    let mut loss = 0.0f32;
    let mut d = vec![0.0f32; scores.len()];
    for (i, &s) in scores.iter().enumerate().skip(1) {
        let viol = margin - s0 + s;
        if viol >= 0.0 {
            loss += viol;
            d[i] += 1.0;
            d[0] -= 1.0;
        }
    }
    (loss, d)
}

/// Squared-error loss for one scalar prediction:
/// `L = (p − t)²`, `∂L/∂p = 2(p − t)`.
pub fn mse(pred: f32, target: f32) -> (f32, f32) {
    let e = pred - target;
    (e * e, 2.0 * e)
}

/// Output of [`softmax_xent_masked`].
#[derive(Debug, Clone)]
pub struct XentGrad {
    /// `Σ_i mask_i · ce_i` — the *unnormalized* masked loss. Callers
    /// that want a mean divide by [`XentGrad::weight`] (and scale
    /// `dlogits` identically); keeping the sum lets a data-parallel
    /// trainer all-reduce partial sums before normalizing once.
    pub total_ce: f32,
    /// `∂ total_ce / ∂ logits` — rows of masked-out roots are zero.
    pub dlogits: Mat,
    /// Per-root `mask_i · ce_i`, in row order (deterministic loss
    /// summation across thread counts).
    pub per_root: Vec<f32>,
    /// `Σ_i mask_i · 1[argmax row i == label_i]`.
    pub correct: f32,
    /// `Σ_i mask_i`.
    pub weight: f32,
}

/// Masked softmax cross-entropy over `[num_roots, num_classes]` logits
/// with integer labels — the loss head of the train step, including the
/// padded-batch root masking (§3.2: padding components get weight 0).
///
/// Numerically stable (per-row max subtraction). A fully masked batch
/// (all weights 0) yields `total_ce == 0` and zero gradients — never
/// NaN.
pub fn softmax_xent_masked(logits: &Mat, labels: &[i32], mask: &[f32]) -> XentGrad {
    assert_eq!(logits.rows, labels.len(), "softmax_xent: labels len");
    assert_eq!(logits.rows, mask.len(), "softmax_xent: mask len");
    let c = logits.cols;
    assert!(c > 0, "softmax_xent: no classes");
    let mut dlogits = Mat::zeros(logits.rows, c);
    let mut total_ce = 0.0f32;
    let mut per_root = Vec::with_capacity(logits.rows);
    let mut correct = 0.0f32;
    let mut weight = 0.0f32;
    for r in 0..logits.rows {
        let m = mask[r];
        if m == 0.0 {
            per_root.push(0.0);
            continue;
        }
        let row = logits.row(r);
        let label = labels[r] as usize;
        assert!(label < c, "softmax_xent: label {label} out of range (classes {c})");
        let mut mx = f32::NEG_INFINITY;
        let mut pred = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                pred = k;
            }
        }
        let mut sumexp = 0.0f32;
        for &v in row {
            sumexp += (v - mx).exp();
        }
        let ce = sumexp.ln() - (row[label] - mx);
        total_ce += m * ce;
        per_root.push(m * ce);
        if pred == label {
            correct += m;
        }
        weight += m;
        let drow = &mut dlogits.data[r * c..(r + 1) * c];
        for (k, (o, &v)) in drow.iter_mut().zip(row).enumerate() {
            let p = (v - mx).exp() / sumexp;
            let onehot = if k == label { 1.0 } else { 0.0 };
            *o = m * (p - onehot);
        }
    }
    XentGrad { total_ce, dlogits, per_root, correct, weight }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Central finite difference of a scalar loss over a flat f32
    /// parameter vector.
    fn fd_grad(x: &[f32], h: f32, eval: &dyn Fn(&[f32]) -> f64) -> Vec<f64> {
        (0..x.len())
            .map(|i| {
                let mut xp = x.to_vec();
                xp[i] += h;
                let mut xm = x.to_vec();
                xm[i] -= h;
                (eval(&xp) - eval(&xm)) / (2.0 * h as f64)
            })
            .collect()
    }

    /// rel err ≤ 1e-3 at f32 (the acceptance tolerance; DESIGN.md
    /// documents the derivation: FD truncation O(h²) plus f32 rounding
    /// noise O(eps·|L|/h) both sit well below 1e-3 at h = 1e-2 for
    /// O(1) values).
    fn check_close(name: &str, analytic: &[f32], numeric: &[f64]) {
        assert_eq!(analytic.len(), numeric.len());
        for (i, (&a, &nm)) in analytic.iter().zip(numeric).enumerate() {
            let denom = (a as f64).abs().max(nm.abs()).max(1.0);
            let e = (a as f64 - nm).abs() / denom;
            assert!(e <= 1e-3, "{name}: grad[{i}] analytic {a} vs fd {nm} (rel {e:.2e})");
        }
    }

    /// Weighted-sum loss `L = Σ w ∘ y` (f64 accumulation) turning any
    /// matrix output into a scalar whose dY is exactly `w`.
    fn wsum(y: &Mat, w: &[f32]) -> f64 {
        y.data.iter().zip(w).map(|(&v, &wv)| v as f64 * wv as f64).sum()
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect()
    }

    /// Random values bounded away from 0 (the relu kink) so finite
    /// differences with h = 1e-2 never cross it.
    fn rand_vec_off_kink(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let v = rng.range_f32(0.05, 2.0);
                if rng.chance(0.5) {
                    -v
                } else {
                    v
                }
            })
            .collect()
    }

    const H: f32 = 1e-2;

    #[test]
    fn gradcheck_matmul() {
        for (seed, (n, k, m)) in
            [(0u64, (3usize, 4usize, 5usize)), (1, (1, 1, 1)), (2, (6, 2, 3))]
        {
            let mut rng = Rng::new(100 + seed);
            let a0 = rand_vec(&mut rng, n * k);
            let w0 = rand_vec(&mut rng, k * m);
            let wt = rand_vec(&mut rng, n * m); // loss weights
            let eval_a = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: k, data: x.to_vec() };
                let w = Mat { rows: k, cols: m, data: w0.clone() };
                wsum(&a.matmul(&w), &wt)
            };
            let eval_w = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: k, data: a0.clone() };
                let w = Mat { rows: k, cols: m, data: x.to_vec() };
                wsum(&a.matmul(&w), &wt)
            };
            let a = Mat { rows: n, cols: k, data: a0.clone() };
            let w = Mat { rows: k, cols: m, data: w0.clone() };
            let dc = Mat { rows: n, cols: m, data: wt.clone() };
            let (da, dw) = matmul_vjp(&a, &w, &dc);
            check_close("matmul dA", &da.data, &fd_grad(&a0, H, &eval_a));
            check_close("matmul dW", &dw.data, &fd_grad(&w0, H, &eval_w));
        }
    }

    #[test]
    fn gradcheck_bias() {
        for (seed, (n, d)) in [(0u64, (4usize, 3usize)), (1, (1, 5)), (2, (7, 1))] {
            let mut rng = Rng::new(200 + seed);
            let x0 = rand_vec(&mut rng, n * d);
            let b0 = rand_vec(&mut rng, d);
            let wt = rand_vec(&mut rng, n * d);
            let eval_b = |bv: &[f32]| -> f64 {
                let mut x = Mat { rows: n, cols: d, data: x0.clone() };
                x.add_bias(bv);
                wsum(&x, &wt)
            };
            let dz = Mat { rows: n, cols: d, data: wt.clone() };
            let db = bias_vjp(&dz);
            check_close("bias db", &db, &fd_grad(&b0, H, &eval_b));
        }
    }

    #[test]
    fn gradcheck_relu() {
        for (seed, (n, d)) in [(0u64, (4usize, 3usize)), (1, (1, 8)), (2, (6, 2))] {
            let mut rng = Rng::new(300 + seed);
            let z0 = rand_vec_off_kink(&mut rng, n * d);
            let wt = rand_vec(&mut rng, n * d);
            let eval = |zv: &[f32]| -> f64 {
                let mut z = Mat { rows: n, cols: d, data: zv.to_vec() };
                z.relu();
                wsum(&z, &wt)
            };
            let z = Mat { rows: n, cols: d, data: z0.clone() };
            let dh = Mat { rows: n, cols: d, data: wt.clone() };
            let dz = relu_vjp(&z, &dh);
            check_close("relu dz", &dz.data, &fd_grad(&z0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_concat() {
        for (seed, widths) in
            [(0u64, vec![2usize, 3]), (1, vec![1, 1, 1]), (2, vec![4, 2, 3])]
        {
            let mut rng = Rng::new(400 + seed);
            let n = 3usize;
            let total: usize = widths.iter().sum();
            let flat0: Vec<f32> = rand_vec(&mut rng, n * total); // all parts, concatenated per part
            let wt = rand_vec(&mut rng, n * total);
            let widths_c = widths.clone();
            let eval = |x: &[f32]| -> f64 {
                // x holds the parts back to back (part-major).
                let mut parts = Vec::new();
                let mut at = 0;
                for &w in &widths_c {
                    parts.push(Mat { rows: n, cols: w, data: x[at..at + n * w].to_vec() });
                    at += n * w;
                }
                let refs: Vec<&Mat> = parts.iter().collect();
                wsum(&Mat::concat_cols(&refs), &wt)
            };
            let dc = Mat { rows: n, cols: total, data: wt.clone() };
            let dparts = concat_cols_vjp(&widths, &dc);
            let analytic: Vec<f32> =
                dparts.iter().flat_map(|p| p.data.iter().copied()).collect();
            check_close("concat dparts", &analytic, &fd_grad(&flat0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_gather() {
        // Includes rows gathered multiple times and rows never gathered.
        for (seed, (n_src, d, idx)) in [
            (0u64, (4usize, 3usize, vec![0i32, 2, 2, 1])),
            (1, (3, 1, vec![2, 2, 2, 2, 2])),
            (2, (5, 2, Vec::new())), // empty gather
        ] {
            let mut rng = Rng::new(500 + seed);
            let x0 = rand_vec(&mut rng, n_src * d);
            let wt = rand_vec(&mut rng, idx.len() * d);
            let idx_c = idx.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: n_src, cols: d, data: x.to_vec() };
                wsum(&m.gather(&idx_c), &wt)
            };
            let dy = Mat { rows: idx.len(), cols: d, data: wt.clone() };
            let dx = gather_vjp(&idx, n_src, &dy);
            check_close("gather dx", &dx.data, &fd_grad(&x0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_segment_sum() {
        // Segment 3 stays empty in the first case; the last case has no
        // rows at all.
        for (seed, (n_seg, d, seg)) in [
            (0u64, (4usize, 2usize, vec![0i32, 1, 1, 0, 2])),
            (1, (2, 3, vec![1, 1, 1])),
            (2, (3, 2, Vec::<i32>::new())),
        ] {
            let mut rng = Rng::new(600 + seed);
            let x0 = rand_vec(&mut rng, seg.len() * d);
            let wt = rand_vec(&mut rng, n_seg * d);
            let seg_c = seg.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: seg_c.len(), cols: d, data: x.to_vec() };
                wsum(&m.segment_sum(&seg_c, n_seg), &wt)
            };
            let dy = Mat { rows: n_seg, cols: d, data: wt.clone() };
            let dx = segment_sum_vjp(&seg, &dy);
            check_close("segment_sum dx", &dx.data, &fd_grad(&x0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_segment_mean() {
        for (seed, (n_seg, d, seg)) in [
            (0u64, (4usize, 2usize, vec![0i32, 1, 1, 0, 2])), // segment 3 empty
            (1, (2, 1, vec![0, 0, 0, 0])),
            (2, (3, 3, vec![2])),
        ] {
            let mut rng = Rng::new(700 + seed);
            let x0 = rand_vec(&mut rng, seg.len() * d);
            let wt = rand_vec(&mut rng, n_seg * d);
            let seg_c = seg.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: seg_c.len(), cols: d, data: x.to_vec() };
                wsum(&segment_mean_fwd(&m, &seg_c, n_seg), &wt)
            };
            let dy = Mat { rows: n_seg, cols: d, data: wt.clone() };
            let dx = segment_mean_vjp(&seg, n_seg, &dy);
            check_close("segment_mean dx", &dx.data, &fd_grad(&x0, H, &eval));
        }
    }

    #[test]
    fn segment_fwd_wrappers_match_ops_layer() {
        let mut rng = Rng::new(42);
        for _ in 0..20 {
            let n = rng.uniform(30);
            let n_seg = 1 + rng.uniform(6);
            let d = 1 + rng.uniform(4);
            let data = rand_vec(&mut rng, n * d);
            let seg: Vec<i32> = (0..n).map(|_| rng.uniform(n_seg) as i32).collect();
            let segs_u: Vec<u32> = seg.iter().map(|&s| s as u32).collect();
            let m = Mat { rows: n, cols: d, data: data.clone() };
            let mean = segment_mean_fwd(&m, &seg, n_seg);
            assert_eq!(mean.data, crate::ops::segment_mean(&data, &segs_u, n_seg, d));
            let (mx, _arg) = segment_max_fwd(&m, &seg, n_seg);
            assert_eq!(mx.data, crate::ops::segment_max(&data, &segs_u, n_seg, d));
        }
    }

    #[test]
    fn gradcheck_segment_max() {
        // Values are spaced ≥ 0.6 apart within each (segment, column)
        // group so the FD step (h = 1e-2) never flips the argmax.
        for (seed, (n_seg, d, seg)) in [
            (0u64, (3usize, 2usize, vec![0i32, 1, 1, 0, 1])), // segment 2 empty
            (1, (2, 1, vec![0, 0, 1, 0])),
            (2, (4, 3, vec![3, 3])),
        ] {
            let mut rng = Rng::new(800 + seed);
            let n = seg.len();
            let mut x0 = vec![0.0f32; n * d];
            for k in 0..d {
                let flip = if rng.chance(0.5) { -1.0f32 } else { 1.0 };
                let mut rank_per_seg = vec![0u32; n_seg];
                for (i, &s) in seg.iter().enumerate() {
                    let rank = rank_per_seg[s as usize];
                    rank_per_seg[s as usize] += 1;
                    x0[i * d + k] = flip * (rank as f32 * 0.7 + rng.range_f32(0.0, 0.1));
                }
            }
            let wt = rand_vec(&mut rng, n_seg * d);
            let seg_c = seg.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: seg_c.len(), cols: d, data: x.to_vec() };
                wsum(&segment_max_fwd(&m, &seg_c, n_seg).0, &wt)
            };
            let m = Mat { rows: n, cols: d, data: x0.clone() };
            let (_y, argmax) = segment_max_fwd(&m, &seg, n_seg);
            let dy = Mat { rows: n_seg, cols: d, data: wt.clone() };
            let dx = segment_max_vjp(&argmax, n, &dy);
            check_close("segment_max dx", &dx.data, &fd_grad(&x0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_broadcast() {
        for (seed, (n_src, d, seg)) in [
            (0u64, (3usize, 2usize, vec![0i32, 2, 2, 1, 0])),
            (1, (1, 4, vec![0, 0])),
            (2, (4, 1, Vec::<i32>::new())),
        ] {
            let mut rng = Rng::new(900 + seed);
            let x0 = rand_vec(&mut rng, n_src * d);
            let wt = rand_vec(&mut rng, seg.len() * d);
            let seg_c = seg.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: n_src, cols: d, data: x.to_vec() };
                wsum(&broadcast_fwd(&m, &seg_c), &wt)
            };
            let dy = Mat { rows: seg.len(), cols: d, data: wt.clone() };
            let dx = broadcast_vjp(&seg, n_src, &dy);
            check_close("broadcast dx", &dx.data, &fd_grad(&x0, H, &eval));
        }
    }

    #[test]
    fn gradcheck_segment_softmax_pool() {
        // Shapes deliberately include a single-edge segment (the
        // softmax collapses to weight 1, dlogits must be exactly 0 up
        // to FD noise) and an empty segment (an all-masked receiver:
        // its dy row must influence nothing).
        for (seed, (n_seg, d, seg)) in [
            (0u64, (4usize, 2usize, vec![0i32, 1, 1, 0, 2])), // seg 2 singleton, seg 3 empty
            (1, (3, 3, vec![2])),                             // single-edge segment + 2 empty
            (2, (5, 1, vec![0, 0, 0, 4, 2, 2])),              // mixed, segs 1 & 3 empty
        ] {
            let mut rng = Rng::new(1100 + seed);
            let n = seg.len();
            let l0 = rand_vec(&mut rng, n);
            let v0 = rand_vec(&mut rng, n * d);
            let wt = rand_vec(&mut rng, n_seg * d);
            let seg_c = seg.clone();
            let v0_c = v0.clone();
            let eval_l = |x: &[f32]| -> f64 {
                let vals = Mat { rows: n, cols: d, data: v0_c.clone() };
                wsum(&segment_softmax_pool_fwd(x, &vals, &seg_c, n_seg).0, &wt)
            };
            let l0_c = l0.clone();
            let eval_v = |x: &[f32]| -> f64 {
                let vals = Mat { rows: n, cols: d, data: x.to_vec() };
                wsum(&segment_softmax_pool_fwd(&l0_c, &vals, &seg_c, n_seg).0, &wt)
            };
            let vals = Mat { rows: n, cols: d, data: v0.clone() };
            let (_y, weights) = segment_softmax_pool_fwd(&l0, &vals, &seg, n_seg);
            let dy = Mat { rows: n_seg, cols: d, data: wt.clone() };
            let (dlogits, dvals) = segment_softmax_pool_vjp(&weights, &vals, &seg, &dy);
            check_close("softmax_pool dlogits", &dlogits, &fd_grad(&l0, H, &eval_l));
            check_close("softmax_pool dvals", &dvals.data, &fd_grad(&v0, H, &eval_v));
        }
    }

    #[test]
    fn segment_softmax_pool_empty_and_singleton_rows() {
        // One edge into segment 1, nothing into segments 0 and 2.
        let vals = Mat { rows: 1, cols: 2, data: vec![3.0, -4.0] };
        let (y, w) = segment_softmax_pool_fwd(&[0.7], &vals, &[1], 3);
        assert_eq!(w, vec![1.0], "singleton softmax weight is exactly 1");
        assert_eq!(y.row(0), &[0.0, 0.0]);
        assert_eq!(y.row(1), &[3.0, -4.0]);
        assert_eq!(y.row(2), &[0.0, 0.0]);
        // Backward: gradients flow only through the real row; a
        // singleton's logit gradient is exactly zero.
        let dy = Mat { rows: 3, cols: 2, data: vec![9.0; 6] };
        let (dl, dv) = segment_softmax_pool_vjp(&w, &vals, &[1], &dy);
        assert_eq!(dl, vec![0.0]);
        assert_eq!(dv.row(0), &[9.0, 9.0]);
        // Fully empty input (every receiver masked out).
        let empty = Mat::zeros(0, 2);
        let (y0, w0) = segment_softmax_pool_fwd(&[], &empty, &[], 2);
        assert!(w0.is_empty());
        assert!(y0.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradcheck_row_dot() {
        for (seed, (n, d)) in [(0u64, (4usize, 3usize)), (1, (1, 6)), (2, (5, 1))] {
            let mut rng = Rng::new(1200 + seed);
            let a0 = rand_vec(&mut rng, n * d);
            let b0 = rand_vec(&mut rng, n * d);
            let wt = rand_vec(&mut rng, n); // per-score loss weights
            let b0_c = b0.clone();
            let eval_a = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: d, data: x.to_vec() };
                let b = Mat { rows: n, cols: d, data: b0_c.clone() };
                row_dot_fwd(&a, &b)
                    .iter()
                    .zip(&wt)
                    .map(|(&s, &w)| s as f64 * w as f64)
                    .sum()
            };
            let a0_c = a0.clone();
            let eval_b = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: d, data: a0_c.clone() };
                let b = Mat { rows: n, cols: d, data: x.to_vec() };
                row_dot_fwd(&a, &b)
                    .iter()
                    .zip(&wt)
                    .map(|(&s, &w)| s as f64 * w as f64)
                    .sum()
            };
            let a = Mat { rows: n, cols: d, data: a0.clone() };
            let b = Mat { rows: n, cols: d, data: b0.clone() };
            let (da, db) = row_dot_vjp(&a, &b, &wt);
            check_close("row_dot dA", &da.data, &fd_grad(&a0, H, &eval_a));
            check_close("row_dot dB", &db.data, &fd_grad(&b0, H, &eval_b));
        }
    }

    #[test]
    fn gradcheck_hadamard() {
        for (seed, (n, d)) in [(0u64, (3usize, 4usize)), (1, (1, 1)), (2, (6, 2))] {
            let mut rng = Rng::new(1300 + seed);
            let a0 = rand_vec(&mut rng, n * d);
            let b0 = rand_vec(&mut rng, n * d);
            let wt = rand_vec(&mut rng, n * d);
            let b0_c = b0.clone();
            let eval_a = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: d, data: x.to_vec() };
                let b = Mat { rows: n, cols: d, data: b0_c.clone() };
                wsum(&hadamard_fwd(&a, &b), &wt)
            };
            let a0_c = a0.clone();
            let eval_b = |x: &[f32]| -> f64 {
                let a = Mat { rows: n, cols: d, data: a0_c.clone() };
                let b = Mat { rows: n, cols: d, data: x.to_vec() };
                wsum(&hadamard_fwd(&a, &b), &wt)
            };
            let a = Mat { rows: n, cols: d, data: a0.clone() };
            let b = Mat { rows: n, cols: d, data: b0.clone() };
            let dy = Mat { rows: n, cols: d, data: wt.clone() };
            let (da, db) = hadamard_vjp(&a, &b, &dy);
            check_close("hadamard dA", &da.data, &fd_grad(&a0, H, &eval_a));
            check_close("hadamard dB", &db.data, &fd_grad(&b0, H, &eval_b));
        }
    }

    #[test]
    fn gradcheck_margin_rank_away_from_hinge() {
        // Scores spaced so no hinge term sits within ±h of its kink —
        // the FD probe must not flip any max(0, ·).
        for (seed, n) in [(0u64, 5usize), (1, 2), (2, 9)] {
            let mut rng = Rng::new(1400 + seed);
            let margin = 1.0f32;
            let s0: Vec<f32> = (0..n)
                .map(|_| {
                    // margin - s0 + si in (-∞, -0.1] ∪ [0.1, ∞)
                    let gap = 0.1 + rng.range_f32(0.0, 1.5);
                    if rng.chance(0.5) {
                        gap
                    } else {
                        -gap
                    }
                })
                .enumerate()
                .map(|(i, v)| if i == 0 { 2.0 } else { 2.0 - margin + v })
                .collect();
            let eval = |x: &[f32]| -> f64 { margin_rank(x, margin).0 as f64 };
            let (_, d) = margin_rank(&s0, margin);
            check_close("margin_rank ds", &d, &fd_grad(&s0, H, &eval));
        }
        // Degenerate cases: a lone positive has zero loss and gradient.
        let (l, d) = margin_rank(&[0.3], 1.0);
        assert_eq!(l, 0.0);
        assert_eq!(d, vec![0.0]);
        // A clearly-violating negative contributes (+1, -1).
        let (l, d) = margin_rank(&[0.0, 2.0], 1.0);
        assert_eq!(l, 3.0);
        assert_eq!(d, vec![-1.0, 1.0]);
    }

    #[test]
    fn gradcheck_mse() {
        let mut rng = Rng::new(1500);
        for _ in 0..10 {
            let p0 = rng.range_f32(-3.0, 3.0);
            let t = rng.range_f32(-3.0, 3.0);
            let eval = |x: &[f32]| -> f64 { mse(x[0], t).0 as f64 };
            let (_, dp) = mse(p0, t);
            check_close("mse dp", &[dp], &fd_grad(&[p0], H, &eval));
        }
        let (l, d) = mse(1.5, 1.5);
        assert_eq!(l, 0.0);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn gradcheck_softmax_xent_with_masked_roots() {
        // Three shapes; every case masks at least one root out (the
        // padded-batch case) and uses a fractional weight.
        for (seed, (r, c)) in [(0u64, (4usize, 5usize)), (1, (1, 3)), (2, (6, 2))] {
            let mut rng = Rng::new(1000 + seed);
            let x0 = rand_vec(&mut rng, r * c);
            let labels: Vec<i32> = (0..r).map(|_| rng.uniform(c) as i32).collect();
            let mut mask: Vec<f32> =
                (0..r).map(|_| if rng.chance(0.3) { 0.0 } else { 1.0 }).collect();
            mask[0] = 0.0; // always at least one masked root
            if r > 1 {
                mask[1] = 0.5; // fractional weight
            }
            let labels_c = labels.clone();
            let mask_c = mask.clone();
            let eval = |x: &[f32]| -> f64 {
                let m = Mat { rows: r, cols: c, data: x.to_vec() };
                softmax_xent_masked(&m, &labels_c, &mask_c).total_ce as f64
            };
            let m = Mat { rows: r, cols: c, data: x0.clone() };
            let g = softmax_xent_masked(&m, &labels, &mask);
            check_close("xent dlogits", &g.dlogits.data, &fd_grad(&x0, H, &eval));
            // Masked rows contribute exactly zero gradient.
            for k in 0..c {
                assert_eq!(g.dlogits.data[k], 0.0, "masked row grad");
            }
            assert_eq!(g.per_root[0], 0.0);
            assert_eq!(g.per_root.len(), r);
        }
    }

    #[test]
    fn xent_all_masked_is_zero_not_nan() {
        let logits = Mat { rows: 3, cols: 4, data: vec![0.5; 12] };
        let g = softmax_xent_masked(&logits, &[0, 1, 2], &[0.0, 0.0, 0.0]);
        assert_eq!(g.total_ce, 0.0);
        assert_eq!(g.weight, 0.0);
        assert_eq!(g.correct, 0.0);
        assert!(g.dlogits.data.iter().all(|&v| v == 0.0));
        assert!(g.total_ce.is_finite());
    }

    #[test]
    fn xent_metrics_count_correct_predictions() {
        // Row 0 predicts class 1 (correct), row 1 predicts class 0
        // (wrong, label 1), row 2 masked out.
        let logits = Mat {
            rows: 3,
            cols: 2,
            data: vec![-1.0, 2.0, 3.0, 0.0, 9.0, -9.0],
        };
        let g = softmax_xent_masked(&logits, &[1, 1, 0], &[1.0, 1.0, 0.0]);
        assert_eq!(g.correct, 1.0);
        assert_eq!(g.weight, 2.0);
        assert!(g.total_ce > 0.0);
    }
}
