//! The trainable native model: a generic GraphUpdate stack.
//!
//! [`NativeModel`] owns a flat parameter list (name → [`Mat`], in a
//! deterministic creation order) plus the [`ModelConfig`] describing
//! the architecture and the validated [`ConvKind`] its edge sets run.
//! The per-layer work — one [`crate::layers::Convolution`] per edge
//! set, merged through the next-state MLP — is delegated to
//! [`crate::layers::GraphUpdate`], so the mpnn that used to be
//! hardwired here is now just one registered configuration of the
//! generic stack (and `tests/native_training.rs` still asserts its
//! per-component logits are **bit-for-bit** the padded AOT bit-level
//! reference, [`crate::ops::model_ref::mpnn_forward_with_config`]).
//!
//! [`NativeModel::forward_tape`] records the [`Tape`]: every pre-relu
//! activation, gathered edge input, softmax weight and index array the
//! reverse sweep needs. [`NativeModel::backward`] walks the tape in
//! reverse, composing the VJP rules of [`super::grad`], and
//! accumulates parameter gradients into a caller-owned flat buffer —
//! which is what makes data-parallel replicas cheap: each replica owns
//! one gradient buffer and the trainer all-reduces them in order.

use std::collections::BTreeMap;

use crate::graph::GraphTensor;
use crate::layers::{ConvDims, ConvKind, GraphUpdate, LayerTape, ModelBuilder};
use crate::ops::model_ref::{encode_dense, root_readout, Mat, ModelConfig};
use crate::runtime::HostTensor;
use crate::train::native::grad;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Everything the backward sweep needs from the *trunk* of one forward
/// pass — encoders, embeddings and GraphUpdate rounds, but no readout
/// head. Tasks ([`crate::tasks`]) run their own readout on top of the
/// final states and seed [`NativeModel::backward_states`] with state
/// gradients.
#[derive(Debug, Clone)]
pub struct TrunkTape {
    /// Pre-relu encoder activations per dense-featured node set.
    pub enc_z: BTreeMap<String, Mat>,
    /// Embedding-gather indices per id-embedding node set.
    pub emb_idx: BTreeMap<String, Vec<i32>>,
    /// Per layer: node set → its update's saved activations.
    pub layers: Vec<LayerTape>,
}

/// Everything the backward sweep needs from one forward pass through
/// the root-classification head (trunk + root readout).
#[derive(Debug, Clone)]
pub struct Tape {
    pub trunk: TrunkTape,
    /// Gathered root states (input of the linear head).
    pub root_states: Mat,
    pub roots: Vec<i32>,
}

/// The trainable model: config + conv kind + named flat parameters.
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub cfg: ModelConfig,
    /// The convolution every edge set runs (`model.type`), validated
    /// by [`ModelBuilder`].
    pub conv: ConvKind,
    /// Parameter names in creation order (encoders, embeddings, layer
    /// updates, head) — the canonical checkpoint/optimizer-state order.
    pub names: Vec<String>,
    pub params: Vec<Mat>,
    index: BTreeMap<String, usize>,
}

fn glorot(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    let s = (6.0 / (rows + cols) as f32).sqrt();
    Mat { rows, cols, data: (0..rows * cols).map(|_| rng.range_f32(-s, s)).collect() }
}

impl NativeModel {
    /// Create a model with Glorot-uniform weights and zero biases,
    /// deterministically from `seed` (the config's `train.init_seed`).
    /// The architecture — which convolution, how many rounds — comes
    /// straight from the config's `model` block via [`ModelBuilder`].
    pub fn init(cfg: ModelConfig, seed: u64) -> Result<NativeModel> {
        let builder = ModelBuilder::from_config(&cfg)?;
        let conv = builder.conv();
        let dims =
            ConvDims { hidden: cfg.hidden, message: cfg.message, att: cfg.att_dim };
        let mut rng = Rng::new(seed);
        let mut names: Vec<String> = Vec::new();
        let mut params: Vec<Mat> = Vec::new();
        for set in &cfg.node_order {
            let feats = cfg
                .features
                .get(set)
                .ok_or_else(|| Error::Schema(format!("no feature list for {set:?}")))?;
            if !feats.is_empty() {
                for fname in feats {
                    let dim = cfg
                        .feature_dims
                        .get(set)
                        .and_then(|m| m.get(fname))
                        .copied()
                        .unwrap_or(0);
                    if dim == 0 {
                        return Err(Error::Schema(format!(
                            "feature {set}/{fname} has no dimension in the config"
                        )));
                    }
                    names.push(format!("enc.{set}.{fname}.w"));
                    params.push(glorot(&mut rng, dim, cfg.hidden));
                }
                names.push(format!("enc.{set}.{}.b", feats[0]));
                params.push(Mat::zeros(1, cfg.hidden));
            } else if cfg.id_embedding.get(set).copied().unwrap_or(false) {
                let card = cfg.cardinality.get(set).copied().ok_or_else(|| {
                    Error::Schema(format!("id-embedding set {set:?} has no cardinality"))
                })?;
                names.push(format!("emb.{set}"));
                params.push(glorot(&mut rng, card, cfg.hidden));
            }
        }
        for layer in 0..cfg.layers {
            for (node_set, edge_list) in &cfg.updates {
                let mut edge_names: Vec<&String> = edge_list.iter().collect();
                edge_names.sort();
                for es in &edge_names {
                    for shape in conv.param_shapes(dims) {
                        names.push(format!("l{layer}.{node_set}.{es}.{}", shape.suffix));
                        params.push(if shape.zero_init {
                            Mat::zeros(shape.rows, shape.cols)
                        } else {
                            glorot(&mut rng, shape.rows, shape.cols)
                        });
                    }
                }
                let in_dim = cfg.hidden + edge_names.len() * conv.out_dim(dims);
                names.push(format!("l{layer}.{node_set}.next.w"));
                params.push(glorot(&mut rng, in_dim, cfg.hidden));
                names.push(format!("l{layer}.{node_set}.next.b"));
                params.push(Mat::zeros(1, cfg.hidden));
            }
        }
        // Readout-head parameters come from the task (config `task`
        // block). The default root-classification head appends
        // `head.w` (Glorot) and `head.b` (zero) exactly as the
        // pre-task-subsystem model did — same draws, same RNG stream,
        // so mpnn parameters stay bit-for-bit reproducible.
        for hp in crate::tasks::head_params(&cfg)? {
            names.push(hp.name.to_string());
            params.push(if hp.zero_init {
                Mat::zeros(hp.rows, hp.cols)
            } else {
                glorot(&mut rng, hp.rows, hp.cols)
            });
        }
        let index = names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        Ok(NativeModel { cfg, conv: builder.kind, names, params, index })
    }

    /// The one-round update view over this model's parameters.
    fn update_view(&self) -> GraphUpdate<'_> {
        GraphUpdate {
            cfg: &self.cfg,
            conv: self.conv.conv(),
            params: &self.params,
            index: &self.index,
        }
    }

    /// Index of a named parameter in the flat list.
    pub fn idx(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| Error::Runtime(format!("native model: no param {name:?}")))
    }

    /// A named parameter.
    pub fn param(&self, name: &str) -> Result<&Mat> {
        Ok(&self.params[self.idx(name)?])
    }

    /// Zeroed gradient buffer matching the parameter list.
    pub fn zeros_grads(&self) -> Vec<Mat> {
        self.params.iter().map(Mat::zeros_like).collect()
    }

    /// Total scalar parameter count.
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| p.data.len()).sum()
    }

    /// Parameters as named host tensors (always rank 2) — the form the
    /// bit-level reference forward, the checkpoint codec and the
    /// serving path consume.
    pub fn params_as_tensors(&self) -> Vec<(String, HostTensor)> {
        self.names
            .iter()
            .zip(&self.params)
            .map(|(n, p)| {
                (n.clone(), HostTensor::F32(vec![p.rows, p.cols], p.data.clone()))
            })
            .collect()
    }

    /// A copy of this model with every parameter replaced from
    /// checkpoint tensors — the serving hot-swap codec path. Accepts
    /// bare names or the AOT runtime's `param.`-prefixed names; extra
    /// tensors (e.g. `adam_m.*` / `adam_v.*` optimizer state saved by
    /// the trainer) are ignored. Every model parameter must be present
    /// with the exact f32 shape, or the whole swap is rejected with a
    /// structured error: a hot-swap is all-or-nothing, never a model
    /// with half its weights replaced.
    pub fn with_tensors(&self, tensors: &[(String, HostTensor)]) -> Result<NativeModel> {
        let mut by_name: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        for (name, t) in tensors {
            let key = name.strip_prefix("param.").unwrap_or(name.as_str());
            by_name.insert(key, t);
        }
        let mut out = self.clone();
        for (name, p) in out.names.iter().zip(out.params.iter_mut()) {
            let t = *by_name.get(name.as_str()).ok_or_else(|| {
                Error::Runtime(format!("checkpoint is missing parameter {name:?}"))
            })?;
            match t {
                HostTensor::F32(shape, data)
                    if shape.as_slice() == [p.rows, p.cols].as_slice() =>
                {
                    p.data.clone_from(data);
                }
                HostTensor::F32(shape, _) => {
                    return Err(Error::Runtime(format!(
                        "checkpoint parameter {name:?} has shape {shape:?}, \
                         model expects [{}, {}]",
                        p.rows, p.cols
                    )));
                }
                _ => {
                    return Err(Error::Runtime(format!(
                        "checkpoint parameter {name:?} is not f32"
                    )));
                }
            }
        }
        Ok(out)
    }

    /// Initial per-node-set states (the MapFeatures stage), returning
    /// the encoder pre-activations and embedding indices for the tape.
    #[allow(clippy::type_complexity)]
    fn initial_states(
        &self,
        g: &GraphTensor,
    ) -> Result<(BTreeMap<String, Mat>, BTreeMap<String, Mat>, BTreeMap<String, Vec<i32>>)>
    {
        let cfg = &self.cfg;
        let mut h = BTreeMap::new();
        let mut enc_z = BTreeMap::new();
        let mut emb_idx = BTreeMap::new();
        for set in &cfg.node_order {
            let n = g.num_nodes(set)?;
            let feats = &cfg.features[set];
            if !feats.is_empty() {
                let mut xs = Vec::with_capacity(feats.len());
                let mut ws = Vec::with_capacity(feats.len());
                for fname in feats {
                    let (dims, data) = g.node_set(set)?.feature(fname)?.as_f32()?;
                    let x = Mat { rows: n, cols: dims[0], data: data.to_vec() };
                    let w = self.param(&format!("enc.{set}.{fname}.w"))?;
                    if x.cols != w.rows {
                        return Err(Error::Feature(format!(
                            "feature {set}/{fname} has dim {}, encoder expects {}",
                            x.cols, w.rows
                        )));
                    }
                    xs.push(x);
                    ws.push(w);
                }
                let b = self.param(&format!("enc.{set}.{}.b", feats[0]))?;
                let (state, z) = encode_dense(&xs, &ws, &b.data);
                h.insert(set.clone(), state);
                enc_z.insert(set.clone(), z);
            } else if cfg.id_embedding.get(set).copied().unwrap_or(false) {
                let (_, ids) = g.node_set(set)?.feature("#id")?.as_i64()?;
                let table = self.param(&format!("emb.{set}"))?;
                let mut idx = Vec::with_capacity(ids.len());
                for &i in ids {
                    if i < 0 || i as usize >= table.rows {
                        return Err(Error::Graph(format!(
                            "{set} id {i} outside embedding table (rows {})",
                            table.rows
                        )));
                    }
                    idx.push(i as i32);
                }
                h.insert(set.clone(), table.gather(&idx));
                emb_idx.insert(set.clone(), idx);
            } else {
                h.insert(set.clone(), Mat::zeros(n, cfg.hidden));
            }
        }
        Ok((h, enc_z, emb_idx))
    }

    /// Final per-node-set hidden states — the trunk forward without a
    /// tape, on the convolutions' fused fast paths. Tasks run their
    /// readout heads over these (eval and serving paths).
    pub fn forward_states(&self, g: &GraphTensor) -> Result<BTreeMap<String, Mat>> {
        let _t = crate::obs::timed(crate::obs_histogram!(
            crate::obs::metrics::names::TRAINER_FORWARD_SECONDS
        ));
        let _span = crate::span!("trainer/forward");
        let (mut h, _enc_z, _emb_idx) = self.initial_states(g)?;
        let view = self.update_view();
        for layer in 0..self.cfg.layers {
            h = view.forward(g, &h, layer)?;
        }
        Ok(h)
    }

    /// Trunk forward recording the [`TrunkTape`]. Bit-for-bit the same
    /// states as [`Self::forward_states`] (each convolution's tape path
    /// is bit-equal to its fused path — the
    /// [`crate::layers::Convolution`] contract).
    pub fn forward_states_tape(
        &self,
        g: &GraphTensor,
    ) -> Result<(BTreeMap<String, Mat>, TrunkTape)> {
        let _t = crate::obs::timed(crate::obs_histogram!(
            crate::obs::metrics::names::TRAINER_FORWARD_SECONDS
        ));
        let _span = crate::span!("trainer/forward_tape");
        let (mut h, enc_z, emb_idx) = self.initial_states(g)?;
        let view = self.update_view();
        let mut layers = Vec::with_capacity(self.cfg.layers);
        for layer in 0..self.cfg.layers {
            let (next, layer_tape) = view.forward_tape(g, &h, layer)?;
            layers.push(layer_tape);
            h = next;
        }
        Ok((h, TrunkTape { enc_z, emb_idx, layers }))
    }

    /// Zeroed `[n, hidden]` state-gradient buffers per node set — what a
    /// task seeds with its readout's state gradients before calling
    /// [`Self::backward_states`].
    pub fn zero_state_grads(&self, g: &GraphTensor) -> Result<BTreeMap<String, Mat>> {
        let mut dh = BTreeMap::new();
        for set in &self.cfg.node_order {
            dh.insert(set.clone(), Mat::zeros(g.num_nodes(set)?, self.cfg.hidden));
        }
        Ok(dh)
    }

    /// Forward pass over one (usually single-component) GraphTensor,
    /// reading out `roots` from `root_set` through the classification
    /// head — **without** a tape. Used by eval and serving.
    pub fn forward_logits(
        &self,
        g: &GraphTensor,
        root_set: &str,
        roots: &[i32],
    ) -> Result<Mat> {
        let h = self.forward_states(g)?;
        let h_root = h
            .get(root_set)
            .ok_or_else(|| Error::Graph(format!("unknown root set {root_set:?}")))?;
        let (logits, _root_states) =
            root_readout(h_root, roots, self.param("head.w")?, &self.param("head.b")?.data);
        Ok(logits)
    }

    /// Forward pass recording the [`Tape`]. Bit-for-bit the same logits
    /// as [`Self::forward_logits`].
    pub fn forward_tape(
        &self,
        g: &GraphTensor,
        root_set: &str,
        roots: &[i32],
    ) -> Result<(Mat, Tape)> {
        let (h, trunk) = self.forward_states_tape(g)?;
        let h_root = h
            .get(root_set)
            .ok_or_else(|| Error::Graph(format!("unknown root set {root_set:?}")))?;
        let (logits, root_states) =
            root_readout(h_root, roots, self.param("head.w")?, &self.param("head.b")?.data);
        Ok((logits, Tape { trunk, root_states, roots: roots.to_vec() }))
    }

    /// Reverse sweep of the trunk: given `dh` (state gradients flowing
    /// into the final hidden states, as seeded by a task's readout
    /// backward), accumulate `∂L/∂params` for encoders, embeddings and
    /// every GraphUpdate round into `grads` — the exact reverse of
    /// [`Self::forward_states_tape`]'s stage order.
    pub fn backward_states(
        &self,
        g: &GraphTensor,
        trunk: &TrunkTape,
        mut dh: BTreeMap<String, Mat>,
        grads: &mut [Mat],
    ) -> Result<()> {
        let _t = crate::obs::timed(crate::obs_histogram!(
            crate::obs::metrics::names::TRAINER_BACKWARD_SECONDS
        ));
        let _span = crate::span!("trainer/backward");
        let cfg = &self.cfg;
        assert_eq!(grads.len(), self.params.len(), "backward_states: grads buffer size");

        // GraphUpdate rounds, in reverse.
        let view = self.update_view();
        for layer in (0..cfg.layers).rev() {
            dh = view.backward(&trunk.layers[layer], layer, &dh, grads)?;
        }

        // Encoders / embeddings.
        for set in &cfg.node_order {
            let d = &dh[set];
            if let Some(z) = trunk.enc_z.get(set) {
                let dz = grad::relu_vjp(z, d);
                let feats = &cfg.features[set];
                for fname in feats {
                    let (dims, data) = g.node_set(set)?.feature(fname)?.as_f32()?;
                    let x = Mat { rows: d.rows, cols: dims[0], data: data.to_vec() };
                    let w_idx = self.idx(&format!("enc.{set}.{fname}.w"))?;
                    let (_dx, d_w) = grad::matmul_vjp(&x, &self.params[w_idx], &dz);
                    grads[w_idx].add_assign(&d_w);
                }
                grads[self.idx(&format!("enc.{set}.{}.b", feats[0]))?]
                    .add_assign(&row_mat(grad::bias_vjp(&dz)));
            } else if let Some(idx) = trunk.emb_idx.get(set) {
                let g_idx = self.idx(&format!("emb.{set}"))?;
                let card = self.params[g_idx].rows;
                grads[g_idx].add_assign(&grad::gather_vjp(idx, card, d));
            }
        }
        Ok(())
    }

    /// Reverse sweep through the classification head: accumulate
    /// `∂L/∂params` into `grads` given `dlogits = ∂L/∂logits` and the
    /// tape of the matching forward. Composes the head VJPs here with
    /// [`Self::backward_states`] — the same float-op order as before the
    /// trunk/head split.
    pub fn backward(
        &self,
        g: &GraphTensor,
        tape: &Tape,
        dlogits: &Mat,
        root_set: &str,
        grads: &mut [Mat],
    ) -> Result<()> {
        assert_eq!(grads.len(), self.params.len(), "backward: grads buffer size");

        // State gradients per node set, flowing backwards through the
        // layers. All states are [n, hidden].
        let mut dh = self.zero_state_grads(g)?;

        // Head / readout.
        let head_w = self.param("head.w")?;
        let (d_root_states, d_head_w) = grad::matmul_vjp(&tape.root_states, head_w, dlogits);
        grads[self.idx("head.w")?].add_assign(&d_head_w);
        grads[self.idx("head.b")?].add_assign(&row_mat(grad::bias_vjp(dlogits)));
        let n_root = g.num_nodes(root_set)?;
        dh.get_mut(root_set)
            .ok_or_else(|| Error::Graph(format!("unknown root set {root_set:?}")))?
            .add_assign(&grad::gather_vjp(&tape.roots, n_root, &d_root_states));

        self.backward_states(g, &tape.trunk, dh, grads)
    }
}

fn row_mat(v: Vec<f32>) -> Mat {
    Mat { rows: 1, cols: v.len(), data: v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_model() -> NativeModel {
        let mag = crate::synth::mag::MagConfig::tiny();
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 2);
        NativeModel::init(cfg, 7).unwrap()
    }

    fn sample_component(seed: u32) -> GraphTensor {
        use std::sync::Arc;
        let ds = crate::synth::mag::generate(&crate::synth::mag::MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec =
            crate::sampler::spec::mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = crate::sampler::inmem::InMemorySampler::new(store, spec, 3).unwrap();
        sampler.sample(seed).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_complete() {
        let a = tiny_model();
        let b = tiny_model();
        assert_eq!(a.conv, ConvKind::Mpnn);
        assert_eq!(a.names, b.names);
        for (x, y) in a.params.iter().zip(&b.params) {
            assert_eq!(x.data, y.data);
        }
        // Canonical entries exist with the reference naming scheme.
        for name in [
            "enc.paper.feat.w",
            "enc.paper.feat.b",
            "emb.institution",
            "emb.field_of_study",
            "l0.paper.cites.msg.w",
            "l1.author.writes.msg.b",
            "l0.author.next.w",
            "head.w",
            "head.b",
        ] {
            assert!(a.idx(name).is_ok(), "missing {name}");
        }
        // paper update pools 3 edge sets: next.w is [h + 3m, h].
        let w = a.param("l0.paper.next.w").unwrap();
        assert_eq!((w.rows, w.cols), (8 + 3 * 8, 8));
        assert!(a.param_elems() > 0);
        // Different seed → different weights.
        let c = NativeModel::init(a.cfg.clone(), 8).unwrap();
        assert_ne!(a.param("head.w").unwrap().data, c.param("head.w").unwrap().data);
    }

    #[test]
    fn init_rejects_invalid_stacks() {
        let mag = crate::synth::mag::MagConfig::tiny();
        let zero_layers = ModelConfig::for_mag(&mag, 8, 8, 0);
        let err = NativeModel::init(zero_layers, 7).expect_err("0 layers rejected");
        assert!(err.to_string().contains("num_layers"), "{err}");
        let unknown = ModelConfig::for_mag(&mag, 8, 8, 1).with_arch("transformer");
        let err = NativeModel::init(unknown, 7).expect_err("unknown type rejected");
        assert!(err.to_string().contains("transformer"), "{err}");
    }

    #[test]
    fn forward_tape_matches_forward_logits_bitexact() {
        let model = tiny_model();
        for seed in [0u32, 3, 11] {
            let g = sample_component(seed);
            let fast = model.forward_logits(&g, "paper", &[0]).unwrap();
            let (taped, tape) = model.forward_tape(&g, "paper", &[0]).unwrap();
            assert_eq!(fast.rows, 1);
            assert_eq!(fast.cols, model.cfg.num_classes);
            for (a, b) in fast.data.iter().zip(&taped.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
            }
            assert_eq!(tape.trunk.layers.len(), model.cfg.layers);
            assert_eq!(tape.root_states.rows, 1);
        }
    }

    /// The same fast==tape bit contract across the whole zoo, at the
    /// model level (heterogeneous MAG schema, all parameter roles).
    #[test]
    fn zoo_forward_tape_matches_forward_logits_bitexact() {
        let mag = crate::synth::mag::MagConfig::tiny();
        for arch in ["gcn", "sage", "gatv2"] {
            let mut cfg = ModelConfig::for_mag(&mag, 8, 8, 2).with_arch(arch);
            if arch == "sage" {
                cfg.sage_reduce = "max".into(); // the trickier reduction
            }
            let model = NativeModel::init(cfg, 7).unwrap();
            assert_eq!(model.conv.name(), arch);
            for seed in [1u32, 6] {
                let g = sample_component(seed);
                let fast = model.forward_logits(&g, "paper", &[0]).unwrap();
                let (taped, _tape) = model.forward_tape(&g, "paper", &[0]).unwrap();
                assert_eq!(fast.cols, model.cfg.num_classes);
                for (a, b) in fast.data.iter().zip(&taped.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{arch} seed {seed}");
                }
            }
        }
    }

    /// End-to-end gradcheck through the whole model: finite differences
    /// on a scattering of parameters across every parameter role must
    /// match the tape backward.
    #[test]
    fn gradcheck_full_model_backward() {
        let model = tiny_model();
        let g = sample_component(5);
        let label = 1i32;
        let loss_of = |m: &NativeModel| -> f64 {
            let logits = m.forward_logits(&g, "paper", &[0]).unwrap();
            grad::softmax_xent_masked(&logits, &[label], &[1.0]).total_ce as f64
        };
        let (logits, tape) = model.forward_tape(&g, "paper", &[0]).unwrap();
        let x = grad::softmax_xent_masked(&logits, &[label], &[1.0]);
        let mut grads = model.zeros_grads();
        model.backward(&g, &tape, &x.dlogits, "paper", &mut grads).unwrap();

        let mut rng = Rng::new(99);
        let h = 1e-2f32;
        let mut checked = 0usize;
        for (pi, name) in model.names.iter().enumerate() {
            let n_elems = model.params[pi].data.len();
            if n_elems == 0 {
                continue;
            }
            // Probe a few random elements of every parameter tensor.
            for _ in 0..3.min(n_elems) {
                let ei = rng.uniform(n_elems);
                let mut mp = model.clone();
                mp.params[pi].data[ei] += h;
                let mut mm = model.clone();
                mm.params[pi].data[ei] -= h;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * h as f64);
                let an = grads[pi].data[ei] as f64;
                let denom = an.abs().max(fd.abs()).max(1.0);
                // Looser than the op-level 1e-3 gate: perturbing a
                // *parameter* can push some downstream pre-activation
                // across the relu kink within ±h (the op-level tests
                // control their inputs to exclude that; a whole model
                // cannot), and f32 rounding accumulates over the full
                // forward. 1e-2 still fails loudly on any structural
                // mistake (a wrong transpose or missing mask is ≥1e-1).
                assert!(
                    (an - fd).abs() / denom <= 1e-2,
                    "{name}[{ei}]: analytic {an} vs fd {fd}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 3 * 8, "probed {checked} elements");
    }

    #[test]
    fn params_roundtrip_as_tensors() {
        let model = tiny_model();
        let tensors = model.params_as_tensors();
        assert_eq!(tensors.len(), model.params.len());
        for ((name, t), p) in tensors.iter().zip(&model.params) {
            assert_eq!(t.shape(), &[p.rows, p.cols], "{name}");
            assert_eq!(t.len(), p.data.len());
        }
    }
}
