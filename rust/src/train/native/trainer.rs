//! Data-parallel native training (paper §6.2 without the AOT runtime).
//!
//! [`NativeTrainer::train_batch`] consumes the same [`Padded`] batches
//! the pipeline emits for the AOT trainer, but runs entirely in Rust:
//!
//! 1. the padded batch's **real components are split back out** (one
//!    rooted subgraph per component — padding contributes nothing and
//!    is dropped, not masked);
//! 2. components (≡ examples) are sharded into `threads` contiguous
//!    **replica chunks**; each replica runs the [`Task`]'s per-example
//!    step — forward-with-tape, the task's readout + loss, and the
//!    tape backward — over its chunk, accumulating an *unnormalized*
//!    gradient sum in chunk order;
//! 3. replica gradients are **all-reduced by deterministic in-order
//!    summation** (replica 0 + replica 1 + …), then scaled by `1/N`;
//! 4. one [`Adam`] step updates the parameters.
//!
//! The objective is supplied by the [`Task`] (root classification,
//! link prediction, graph regression — see [`crate::tasks`]); the
//! historical constructor [`NativeTrainer::new`] still takes a
//! [`RootTask`] and builds the classification task from it, so the
//! pre-subsystem call sites (and their bit-parity guarantees) are
//! untouched.
//!
//! Determinism contract (asserted in `tests/native_training.rs`,
//! `tests/tasks.rs` and `benches/{training,tasks}.rs` before any
//! timing):
//! * at 1 thread the step is **bit-for-bit** [`train_step_oracle_task`]
//!   (the plain serial loop kept as the reference);
//! * at any thread count the reported loss is the in-example-order sum
//!   of per-example losses (replica chunks are contiguous), so a
//!   single step's loss is bit-stable across thread counts; parameter
//!   updates differ only by the reduction grouping (≤1e-5 rel drift).

use std::path::Path;
use std::sync::Arc;

use crate::graph::pad::Padded;
use crate::graph::GraphTensor;
use crate::obs::events::{GradStats, LayerStats, Telemetry};
use crate::obs::metrics::names;
use crate::ops::model_ref::Mat;
use crate::runtime::batch::RootTask;
use crate::tasks::{RootClassification, Task};
use crate::train::metrics::TaskMetrics;
use crate::train::native::model::NativeModel;
use crate::train::native::optim::{state_from_tensors, state_to_tensors, Adam, AdamConfig};
use crate::train::StepMetrics;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// One replica's contribution: unnormalized gradient sums, per-example
/// losses (in chunk order) and the chunk's metric sums.
struct ChunkOut {
    grads: Vec<Mat>,
    losses: Vec<f64>,
    metrics: TaskMetrics,
}

/// Task step + backward over one contiguous chunk of components. This
/// is the exact per-replica computation — the serial oracle is this
/// function applied to the whole batch as one chunk.
fn chunk_grad(
    model: &NativeModel,
    task: &dyn Task,
    comps: &[GraphTensor],
) -> Result<ChunkOut> {
    let mut grads = model.zeros_grads();
    let mut losses = Vec::with_capacity(comps.len());
    let mut metrics = TaskMetrics::default();
    for g in comps {
        let s = task.step_grad(model, g, &mut grads)?;
        losses.push(s.loss);
        metrics.merge(&s.metrics);
    }
    Ok(ChunkOut { grads, losses, metrics })
}

/// Forward-only counterpart of [`chunk_grad`]: per-example losses (in
/// chunk order) and the chunk's metric sums.
fn chunk_eval(
    model: &NativeModel,
    task: &dyn Task,
    comps: &[GraphTensor],
) -> Result<(Vec<f64>, TaskMetrics)> {
    let mut losses = Vec::with_capacity(comps.len());
    let mut metrics = TaskMetrics::default();
    for g in comps {
        let s = task.step_eval(model, g)?;
        losses.push(s.loss);
        metrics.merge(&s.metrics);
    }
    Ok((losses, metrics))
}

/// Partition components into contiguous chunks of `size` — the replica
/// sharding used by both train and eval (contiguity is what keeps
/// per-example loss order, and therefore the reported loss, identical
/// at every thread count).
fn split_chunks(size: usize, comps: Vec<GraphTensor>) -> Vec<Vec<GraphTensor>> {
    let mut items = Vec::new();
    let mut it = comps.into_iter();
    loop {
        let c: Vec<GraphTensor> = it.by_ref().take(size).collect();
        if c.is_empty() {
            break;
        }
        items.push(c);
    }
    items
}

/// Split a padded batch into its real components (one example each;
/// label/target reading is the task's concern).
fn real_components(padded: &Padded) -> Result<Vec<GraphTensor>> {
    let mut comps = crate::graph::batch::split(&padded.graph)?;
    comps.truncate(padded.num_real_components);
    Ok(comps)
}

/// Fold replica outputs in strict replica-index order and assemble the
/// step metrics (mean loss over `n` examples, in-order f64 loss sum).
fn reduce_outs(outs: Vec<ChunkOut>, n: usize) -> (Vec<Mat>, StepMetrics) {
    let mut outs_it = outs.into_iter();
    // Callers only reduce non-empty batches; an empty fold degrades to
    // an all-zero step rather than panicking.
    let Some(first) = outs_it.next() else {
        return (Vec::new(), StepMetrics::default());
    };
    let mut grads = first.grads;
    let mut losses = first.losses;
    let mut metrics = first.metrics;
    for o in outs_it {
        for (a, b) in grads.iter_mut().zip(&o.grads) {
            a.add_assign(b);
        }
        losses.extend(o.losses);
        metrics.merge(&o.metrics);
    }
    // Mean over the batch's real examples, applied once after the
    // reduce (identical in the serial oracle).
    let inv = 1.0f32 / n as f32;
    for gm in &mut grads {
        gm.scale(inv);
    }
    // Loss: in-example-order f64 sum — losses is in global component
    // order because chunks are contiguous.
    let loss_sum: f64 = losses.iter().sum();
    let step = StepMetrics {
        loss: (loss_sum / n as f64) as f32,
        correct: metrics.correct as f32,
        weight: n as f32,
        task: metrics,
    };
    (grads, step)
}

/// The native data-parallel trainer: model + task + Adam state +
/// replica pool.
pub struct NativeTrainer {
    /// Shared with in-flight replica closures during a step; updated
    /// via copy-on-write after the all-reduce.
    model: Arc<NativeModel>,
    pub opt: Adam,
    /// The training objective (readout head + loss + metrics).
    pub task: Arc<dyn Task>,
    threads: usize,
    pool: Option<ThreadPool>,
    pub steps_done: u64,
    /// Gradient-health probes + event-journal/flight-recorder hooks
    /// (all off by default — the default-off trainer is bit-for-bit
    /// the pre-telemetry trainer).
    telemetry: Telemetry,
    /// The most recent step's probe results, handed to the runner's
    /// epoch loop via [`NativeTrainer::take_grad_stats`].
    last_grad_stats: Option<GradStats>,
}

impl NativeTrainer {
    /// The historical constructor: root classification bound by a
    /// [`RootTask`]. `threads == 0 | 1` trains serially (the oracle
    /// path); `threads > 1` spawns that many replica workers once,
    /// reused every step.
    pub fn new(
        model: NativeModel,
        adam: AdamConfig,
        task: RootTask,
        threads: usize,
    ) -> NativeTrainer {
        NativeTrainer::with_task(
            model,
            adam,
            Arc::new(RootClassification {
                root_set: task.root_set,
                label_feature: task.label_feature,
            }),
            threads,
        )
    }

    /// Construct with an explicit task (link prediction, regression, or
    /// a custom head).
    pub fn with_task(
        model: NativeModel,
        adam: AdamConfig,
        task: Arc<dyn Task>,
        threads: usize,
    ) -> NativeTrainer {
        let opt = Adam::new(adam, &model.params);
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        NativeTrainer {
            model: Arc::new(model),
            opt,
            task,
            threads: threads.max(1),
            pool,
            steps_done: 0,
            telemetry: Telemetry::default(),
            last_grad_stats: None,
        }
    }

    /// Install telemetry hooks (gradient probes, sentinel limit,
    /// flight recorder, event journal). Probes are read-only observers
    /// of the reduced gradients: enabling them changes no trained bit
    /// (pinned by `tests/events.rs`).
    pub fn set_telemetry(&mut self, telemetry: Telemetry) {
        self.telemetry = telemetry;
    }

    /// The probe results of the most recent [`Self::train_batch`], if
    /// probes were on; taking them resets the slot.
    pub fn take_grad_stats(&mut self) -> Option<GradStats> {
        self.last_grad_stats.take()
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// One data-parallel training step over a padded batch.
    pub fn train_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        let _span = crate::span!("trainer/step", threads = self.threads);
        let comps = real_components(padded)?;
        let n = comps.len();
        if n == 0 {
            return Ok(StepMetrics { loss: 0.0, correct: 0.0, weight: 0.0, ..Default::default() });
        }
        let chunks = self.threads.min(n);
        // `pool` is Some iff threads > 1; a missing pool degrades to
        // the serial oracle path rather than panicking.
        let outs: Vec<ChunkOut> = match self.pool.as_ref().filter(|_| chunks > 1) {
            Some(pool) => {
                let items = split_chunks(n.div_ceil(chunks), comps);
                let model = Arc::clone(&self.model);
                let task = Arc::clone(&self.task);
                pool.map(items, move |c| chunk_grad(&model, task.as_ref(), &c))
                    .into_iter()
                    .collect::<Result<Vec<_>>>()?
            }
            None => vec![chunk_grad(&self.model, self.task.as_ref(), &comps)?],
        };

        // All-reduce: strictly in replica-index order, so the summation
        // tree depends only on the chunking, never on scheduling.
        let (grads, step) = {
            let _t = crate::obs::timed(crate::obs_histogram!(
                crate::obs::metrics::names::TRAINER_ALLREDUCE_SECONDS
            ));
            let _span = crate::span!("trainer/allreduce", replicas = n);
            reduce_outs(outs, n)
        };

        // Gradient-health probes: read-only f64 accumulation over the
        // reduced gradients — never fed back into the update, so the
        // trained bits are identical with probes on or off. A sentinel
        // trip returns *before* the optimizer step: the parameters are
        // left at their last healthy state instead of diverging.
        let probe = if self.telemetry.probes_on() {
            Some(self.probe_gradients(&grads)?)
        } else {
            None
        };
        // Update-ratio needs the pre-step parameters; the clone happens
        // only in telemetry mode (and is never written to).
        let prev_params = probe.as_ref().map(|_| self.model.params.clone());

        {
            let _t = crate::obs::timed(crate::obs_histogram!(
                crate::obs::metrics::names::TRAINER_OPTIMIZER_SECONDS
            ));
            let _span = crate::span!("trainer/optimizer");
            let model = Arc::make_mut(&mut self.model);
            self.opt.step(&mut model.params, &grads);
        }
        self.steps_done += 1;
        crate::obs_counter!(crate::obs::metrics::names::TRAINER_STEPS).inc();

        if let (Some(mut stats), Some(prev)) = (probe, prev_params) {
            let mut sumsq = 0.0f64;
            for (now, before) in self.model.params.iter().zip(&prev) {
                for (a, b) in now.data.iter().zip(&before.data) {
                    let d = f64::from(*a) - f64::from(*b);
                    sumsq += d * d;
                }
            }
            stats.update_norm = sumsq.sqrt();
            stats.update_ratio = if stats.param_norm > 0.0 {
                stats.update_norm / stats.param_norm
            } else {
                0.0
            };
            if crate::obs::recording() {
                crate::obs_histogram!(names::TRAINER_GRAD_NORM).record(stats.grad_norm);
                crate::obs_histogram!(names::TRAINER_UPDATE_RATIO).record(stats.update_ratio);
            }
            self.last_grad_stats = Some(stats);
        }
        Ok(step)
    }

    /// Compute global + per-layer-group gradient/parameter L2 norms
    /// and run the NaN/Inf and explosion sentinels. Errors name the
    /// step and the offending tensor, and fire the flight recorder
    /// (with the recent event-journal tail) before returning.
    fn probe_gradients(&self, grads: &[Mat]) -> Result<GradStats> {
        let step = self.steps_done;
        let mut layers: Vec<LayerStats> = Vec::new();
        let mut grad_sumsq = 0.0f64;
        let mut param_sumsq = 0.0f64;
        let mut offender: Option<&str> = None;
        let mut largest: (f64, &str) = (-1.0, "");
        for ((name, g), p) in self.model.names.iter().zip(grads).zip(&self.model.params) {
            let mut gs = 0.0f64;
            for &v in &g.data {
                let v = f64::from(v);
                gs += v * v;
            }
            let mut ps = 0.0f64;
            for &v in &p.data {
                let v = f64::from(v);
                ps += v * v;
            }
            if !gs.is_finite() && offender.is_none() {
                offender = Some(name);
            }
            if gs > largest.0 {
                largest = (gs, name);
            }
            grad_sumsq += gs;
            param_sumsq += ps;
            // Layer groups by name prefix ("l0.w" -> "l0"); parameter
            // creation order keeps each group's tensors contiguous.
            let group = name.split('.').next().unwrap_or(name);
            match layers.last_mut() {
                Some(l) if l.name == group => {
                    l.grad_norm += gs;
                    l.param_norm += ps;
                }
                _ => layers.push(LayerStats {
                    name: group.to_string(),
                    grad_norm: gs,
                    param_norm: ps,
                }),
            }
        }
        if let Some(name) = offender {
            crate::obs_counter!(names::TRAINER_GRAD_NONFINITE).inc();
            let detail = format!("non-finite gradient in tensor {name:?} at step {step}");
            self.fire_sentinel("grad-nonfinite", &detail);
            return Err(Error::Runtime(format!(
                "gradient health: non-finite gradient in tensor {name:?} at step {step} \
                 (parameters left at their last healthy state)"
            )));
        }
        let grad_norm = grad_sumsq.sqrt();
        if let Some(limit) = self.telemetry.grad_norm_limit {
            if grad_norm > limit {
                crate::obs_counter!(names::TRAINER_GRAD_EXPLOSIONS).inc();
                let worst = largest.1;
                let detail = format!(
                    "global gradient norm {grad_norm:.3e} exceeds limit {limit:.3e} at \
                     step {step} (largest tensor {worst:?})"
                );
                self.fire_sentinel("grad-explosion", &detail);
                return Err(Error::Runtime(format!(
                    "gradient health: global gradient norm {grad_norm:.3e} exceeds limit \
                     {limit:.3e} at step {step} (largest tensor {worst:?}; parameters left \
                     at their last healthy state)"
                )));
            }
        }
        // Layer sums -> norms only on the healthy path (the sentinels
        // above only need the global norm).
        for l in &mut layers {
            l.grad_norm = l.grad_norm.sqrt();
            l.param_norm = l.param_norm.sqrt();
        }
        Ok(GradStats {
            step,
            grad_norm,
            param_norm: param_sumsq.sqrt(),
            update_norm: 0.0,
            update_ratio: 0.0,
            layers,
        })
    }

    /// Fire the flight recorder (if configured) with the recent event
    /// tail attached — the dump shows the steps leading into the trip.
    fn fire_sentinel(&self, trigger: &str, detail: &str) {
        if let Some(flight) = &self.telemetry.flight {
            let tail = self.telemetry.journal.as_ref().map(|j| j.tail()).unwrap_or_default();
            let _ = flight.record_with(trigger, detail, vec![("events", Json::Arr(tail))]);
        }
    }

    /// Evaluate a padded batch (forward only, no state change),
    /// replica-parallel like training.
    pub fn eval_batch(&self, padded: &Padded) -> Result<StepMetrics> {
        let comps = real_components(padded)?;
        let n = comps.len();
        if n == 0 {
            return Ok(StepMetrics { loss: 0.0, correct: 0.0, weight: 0.0, ..Default::default() });
        }
        let chunks = self.threads.min(n);
        let parts: Vec<(Vec<f64>, TaskMetrics)> = match self.pool.as_ref().filter(|_| chunks > 1) {
            Some(pool) => {
                let items = split_chunks(n.div_ceil(chunks), comps);
                let model = Arc::clone(&self.model);
                let task = Arc::clone(&self.task);
                pool.map(items, move |c| chunk_eval(&model, task.as_ref(), &c))
                    .into_iter()
                    .collect::<Result<Vec<_>>>()?
            }
            None => vec![chunk_eval(&self.model, self.task.as_ref(), &comps)?],
        };
        let mut loss_sum = 0.0f64;
        let mut metrics = TaskMetrics::default();
        for (losses, m) in parts {
            loss_sum += losses.iter().sum::<f64>();
            metrics.merge(&m);
        }
        Ok(StepMetrics {
            loss: (loss_sum / n as f64) as f32,
            correct: metrics.correct as f32,
            weight: n as f32,
            task: metrics,
        })
    }

    /// Save full trainer state (`param.* ++ adam_m.* ++ adam_v.* ++
    /// step`) through the shared binary checkpoint codec.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tensors = state_to_tensors(&self.model.names, &self.model.params, &self.opt);
        crate::train::checkpoint::save(path, &tensors)
    }

    /// Restore state saved by [`Self::save`] (names and shapes must
    /// match this trainer's model).
    pub fn load(&mut self, path: &Path) -> Result<()> {
        let tensors = crate::train::checkpoint::load(path)?;
        let (params, m, v, steps) =
            state_from_tensors(&self.model.names, &self.model.params, &tensors)?;
        let model = Arc::make_mut(&mut self.model);
        model.params = params;
        self.opt.m = m;
        self.opt.v = v;
        self.opt.steps = steps;
        self.steps_done = steps;
        Ok(())
    }
}

/// The serial oracle for any task: the same step math as a 1-thread
/// [`NativeTrainer::train_batch`], written as one plain loop with no
/// pool, no chunking and no copy-on-write — kept as the bit-for-bit
/// reference the parallel path is tested against.
pub fn train_step_oracle_task(
    model: &mut NativeModel,
    opt: &mut Adam,
    padded: &Padded,
    task: &dyn Task,
) -> Result<StepMetrics> {
    let comps = real_components(padded)?;
    let n = comps.len();
    if n == 0 {
        return Ok(StepMetrics { loss: 0.0, correct: 0.0, weight: 0.0, ..Default::default() });
    }
    let mut grads = model.zeros_grads();
    let mut losses: Vec<f64> = Vec::with_capacity(n);
    let mut metrics = TaskMetrics::default();
    for g in &comps {
        let s = task.step_grad(model, g, &mut grads)?;
        losses.push(s.loss);
        metrics.merge(&s.metrics);
    }
    let inv = 1.0f32 / n as f32;
    for gm in &mut grads {
        gm.scale(inv);
    }
    let loss_sum: f64 = losses.iter().sum();
    opt.step(&mut model.params, &grads);
    Ok(StepMetrics {
        loss: (loss_sum / n as f64) as f32,
        correct: metrics.correct as f32,
        weight: n as f32,
        task: metrics,
    })
}

/// [`train_step_oracle_task`] bound to root classification — the
/// historical signature the pre-subsystem tests and benches drive.
pub fn train_step_oracle(
    model: &mut NativeModel,
    opt: &mut Adam,
    padded: &Padded,
    task: &RootTask,
) -> Result<StepMetrics> {
    let rc = RootClassification {
        root_set: task.root_set.clone(),
        label_feature: task.label_feature.clone(),
    };
    train_step_oracle_task(model, opt, padded, &rc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pad::{fit_or_skip, PadSpec};
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig};

    fn tiny_batches(batch: usize, count: usize) -> Vec<Padded> {
        let ds = generate(&MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let probe: Vec<_> = (0..8u32).map(|s| sampler.sample(s).unwrap()).collect();
        let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.5);
        let mut out = Vec::new();
        let mut seed = 0u32;
        while out.len() < count {
            let graphs: Vec<_> =
                (0..batch).map(|i| sampler.sample(seed + i as u32).unwrap()).collect();
            seed += batch as u32;
            let merged = crate::graph::batch::merge(&graphs).unwrap();
            if let Some(p) = fit_or_skip(&merged, &pad) {
                out.push(p);
            }
        }
        out
    }

    fn tiny_model() -> NativeModel {
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2);
        NativeModel::init(cfg, 11).unwrap()
    }

    #[test]
    fn empty_real_components_is_a_zero_weight_step() {
        // A batch whose every component is padding (num_real = 0).
        let batches = tiny_batches(2, 1);
        let mut padded = batches[0].clone();
        padded.num_real_components = 0;
        let mut t = NativeTrainer::new(tiny_model(), AdamConfig::default(), RootTask::default(), 1);
        let m = t.train_batch(&padded).unwrap();
        assert_eq!(m.weight, 0.0);
        assert_eq!(m.loss, 0.0);
        assert!(m.loss.is_finite());
        assert_eq!(t.steps_done, 0, "no step applied on an empty batch");
        let e = t.eval_batch(&padded).unwrap();
        assert_eq!(e.weight, 0.0);
    }

    /// A label outside the model's class range (train.num_classes and
    /// dataset.num_classes disagreeing in a config) must surface as a
    /// structured error, not a panic inside a replica thread.
    #[test]
    fn out_of_range_label_is_an_error_not_a_panic() {
        let ds = generate(&MagConfig::tiny());
        // Pick roots whose labels provably exceed the shrunken range.
        let bad_seeds: Vec<u32> = ds
            .labels
            .iter()
            .enumerate()
            .filter(|(_, &l)| l >= 2)
            .take(2)
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(bad_seeds.len(), 2, "tiny MAG should have labels ≥ 2");
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let graphs: Vec<_> =
            bad_seeds.iter().map(|&s| sampler.sample(s).unwrap()).collect();
        let pad = PadSpec::fit(&graphs.iter().collect::<Vec<_>>(), 2, 2.0);
        let merged = crate::graph::batch::merge(&graphs).unwrap();
        let padded = fit_or_skip(&merged, &pad).unwrap();

        let mut cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1);
        cfg.num_classes = 2; // tiny MAG labels run 0..4
        let model = NativeModel::init(cfg, 11).unwrap();
        let mut t = NativeTrainer::new(model, AdamConfig::default(), RootTask::default(), 2);
        let err = t.train_batch(&padded).expect_err("bad label must error");
        assert!(err.to_string().contains("num_classes"), "{err}");
        let err = t.eval_batch(&padded).expect_err("bad label must error in eval");
        assert!(err.to_string().contains("num_classes"), "{err}");
    }

    #[test]
    fn parallel_eval_matches_serial_eval() {
        let batches = tiny_batches(4, 2);
        let model = tiny_model();
        let t1 = NativeTrainer::new(model.clone(), AdamConfig::default(), RootTask::default(), 1);
        let t4 = NativeTrainer::new(model, AdamConfig::default(), RootTask::default(), 4);
        for b in &batches {
            let a = t1.eval_batch(b).unwrap();
            let p = t4.eval_batch(b).unwrap();
            assert_eq!(a.loss.to_bits(), p.loss.to_bits(), "in-order loss sum is thread-stable");
            assert_eq!(a.correct, p.correct);
            assert_eq!(a.weight, p.weight);
        }
    }

    #[test]
    fn checkpoint_roundtrip_restores_training_state() {
        let batches = tiny_batches(4, 2);
        let mut t = NativeTrainer::new(tiny_model(), AdamConfig::default(), RootTask::default(), 2);
        t.train_batch(&batches[0]).unwrap();
        t.train_batch(&batches[1]).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("tfgnn-native-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        t.save(&path).unwrap();
        let after_save = t.train_batch(&batches[0]).unwrap();

        let mut t2 =
            NativeTrainer::new(tiny_model(), AdamConfig::default(), RootTask::default(), 2);
        t2.load(&path).unwrap();
        assert_eq!(t2.steps_done, 2);
        assert_eq!(t2.opt.steps, 2);
        let after_load = t2.train_batch(&batches[0]).unwrap();
        assert_eq!(
            after_save.loss.to_bits(),
            after_load.loss.to_bits(),
            "restored trainer continues identically"
        );
        for (a, b) in t.model().params.iter().zip(&t2.model().params) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
