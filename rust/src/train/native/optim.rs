//! Adam with decoupled weight decay over flat `Vec<Mat>` state, plus
//! the checkpoint layout of the native trainer.
//!
//! The optimizer state mirrors the AOT trainer's device layout
//! (`params ++ adam_m ++ adam_v ++ step`) and round-trips through
//! [`crate::train::checkpoint`]'s binary codec via
//! [`state_to_tensors`] / [`state_from_tensors`]: every tensor is
//! saved rank-2 under `param.<name>` / `adam_m.<name>` /
//! `adam_v.<name>`, with the step counter as a scalar i64 `step` slot.

use crate::ops::model_ref::Mat;
use crate::runtime::HostTensor;
use crate::util::json::Json;
use crate::{Error, Result};

/// Adam hyper-parameters (decoupled weight decay, AdamW-style:
/// `p -= lr · (m̂ / (√v̂ + eps) + wd · p)`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> AdamConfig {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl AdamConfig {
    /// Read from a run config's `train` object (`learning_rate`,
    /// `weight_decay`, `adam_beta1/2`, `adam_eps`; missing keys keep
    /// the defaults).
    pub fn from_train_config(cfg: &Json) -> Result<AdamConfig> {
        let t = cfg.get("train")?;
        let mut a = AdamConfig::default();
        if let Some(v) = t.opt("learning_rate") {
            a.lr = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("weight_decay") {
            a.weight_decay = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("adam_beta1") {
            a.beta1 = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("adam_beta2") {
            a.beta2 = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("adam_eps") {
            a.eps = v.as_f64()? as f32;
        }
        Ok(a)
    }
}

/// Adam state: first/second moments per parameter, plus the step count.
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    pub m: Vec<Mat>,
    pub v: Vec<Mat>,
    /// Completed steps (bias correction uses `t = steps + 1`).
    pub steps: u64,
}

impl Adam {
    /// Zero moments shaped like `params`.
    pub fn new(cfg: AdamConfig, params: &[Mat]) -> Adam {
        Adam {
            cfg,
            m: params.iter().map(Mat::zeros_like).collect(),
            v: params.iter().map(Mat::zeros_like).collect(),
            steps: 0,
        }
    }

    /// Apply one update in place. `grads` must be parallel to `params`
    /// (same order and shapes).
    pub fn step(&mut self, params: &mut [Mat], grads: &[Mat]) {
        assert_eq!(params.len(), grads.len(), "adam: grads len");
        assert_eq!(params.len(), self.m.len(), "adam: state len");
        let t = self.steps + 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(t as i32);
        let bc2 = 1.0 - c.beta2.powi(t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.rows, g.rows, "adam: shape");
            assert_eq!(p.cols, g.cols, "adam: shape");
            for k in 0..p.data.len() {
                let gk = g.data[k];
                let mk = c.beta1 * m.data[k] + (1.0 - c.beta1) * gk;
                let vk = c.beta2 * v.data[k] + (1.0 - c.beta2) * gk * gk;
                m.data[k] = mk;
                v.data[k] = vk;
                let m_hat = mk / bc1;
                let v_hat = vk / bc2;
                let pk = p.data[k];
                p.data[k] = pk - c.lr * (m_hat / (v_hat.sqrt() + c.eps) + c.weight_decay * pk);
            }
        }
        self.steps = t;
    }
}

/// Serialize native trainer state as named tensors in the AOT layout:
/// `param.*` ++ `adam_m.*` ++ `adam_v.*` ++ `step`.
pub fn state_to_tensors(
    names: &[String],
    params: &[Mat],
    adam: &Adam,
) -> Vec<(String, HostTensor)> {
    let mat_t = |m: &Mat| HostTensor::F32(vec![m.rows, m.cols], m.data.clone());
    let mut out = Vec::with_capacity(3 * names.len() + 1);
    for (n, p) in names.iter().zip(params) {
        out.push((format!("param.{n}"), mat_t(p)));
    }
    for (n, m) in names.iter().zip(&adam.m) {
        out.push((format!("adam_m.{n}"), mat_t(m)));
    }
    for (n, v) in names.iter().zip(&adam.v) {
        out.push((format!("adam_v.{n}"), mat_t(v)));
    }
    out.push(("step".to_string(), HostTensor::I64(vec![], vec![adam.steps as i64])));
    out
}

/// Inverse of [`state_to_tensors`]: validate names/shapes against the
/// model's canonical order and rebuild `(params, m, v, steps)`.
pub fn state_from_tensors(
    names: &[String],
    shapes: &[Mat],
    tensors: &[(String, HostTensor)],
) -> Result<(Vec<Mat>, Vec<Mat>, Vec<Mat>, u64)> {
    let n = names.len();
    if tensors.len() != 3 * n + 1 {
        return Err(Error::Codec(format!(
            "native checkpoint has {} tensors, model wants {}",
            tensors.len(),
            3 * n + 1
        )));
    }
    let read_block = |offset: usize, prefix: &str| -> Result<Vec<Mat>> {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let (tname, t) = &tensors[offset + i];
            let want = format!("{prefix}.{}", names[i]);
            if tname != &want {
                return Err(Error::Codec(format!(
                    "native checkpoint slot {} is {tname:?}, expected {want:?}",
                    offset + i
                )));
            }
            let (shape, data) = match t {
                HostTensor::F32(s, d) => (s, d),
                _ => return Err(Error::Codec(format!("{want}: not f32"))),
            };
            let expect = &shapes[i];
            if shape.as_slice() != &[expect.rows, expect.cols][..] {
                return Err(Error::Codec(format!(
                    "{want}: shape {shape:?}, model wants [{}, {}]",
                    expect.rows, expect.cols
                )));
            }
            out.push(Mat { rows: expect.rows, cols: expect.cols, data: data.clone() });
        }
        Ok(out)
    };
    let params = read_block(0, "param")?;
    let m = read_block(n, "adam_m")?;
    let v = read_block(2 * n, "adam_v")?;
    let (sname, st) = &tensors[3 * n];
    if sname != "step" {
        return Err(Error::Codec(format!("last slot is {sname:?}, expected \"step\"")));
    }
    let steps = match st {
        HostTensor::I64(_, d) if d.len() == 1 => d[0] as u64,
        _ => return Err(Error::Codec("step slot is not a scalar i64".into())),
    };
    Ok((params, m, v, steps))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, f: impl Fn(usize) -> f32) -> Mat {
        Mat { rows, cols, data: (0..rows * cols).map(f).collect() }
    }

    #[test]
    fn adam_first_step_moves_against_gradient() {
        let cfg = AdamConfig { lr: 0.1, ..AdamConfig::default() };
        let mut params = vec![mat(1, 3, |_| 1.0)];
        let grads = vec![mat(1, 3, |i| if i == 0 { 2.0 } else { -2.0 })];
        let mut adam = Adam::new(cfg, &params);
        adam.step(&mut params, &grads);
        // First step: m̂/√v̂ ≈ sign(g), so p moves ≈ lr against g.
        assert!(params[0].data[0] < 1.0);
        assert!(params[0].data[1] > 1.0);
        assert!((params[0].data[0] - 0.9).abs() < 1e-3);
        assert_eq!(adam.steps, 1);
    }

    #[test]
    fn adam_zero_grad_with_weight_decay_shrinks_params() {
        let cfg = AdamConfig { lr: 0.1, weight_decay: 0.5, ..AdamConfig::default() };
        let mut params = vec![mat(2, 2, |_| 1.0)];
        let grads = vec![mat(2, 2, |_| 0.0)];
        let mut adam = Adam::new(cfg, &params);
        adam.step(&mut params, &grads);
        for &v in &params[0].data {
            assert!((v - 0.95).abs() < 1e-6, "decoupled decay: 1 - lr*wd, got {v}");
        }
    }

    #[test]
    fn adam_is_deterministic() {
        let cfg = AdamConfig::default();
        let run = || {
            let mut params = vec![mat(2, 3, |i| i as f32 * 0.1), mat(1, 2, |_| -0.5)];
            let mut adam = Adam::new(cfg, &params);
            for s in 0..5 {
                let grads = vec![
                    mat(2, 3, |i| (i + s) as f32 * 0.01 - 0.02),
                    mat(1, 2, |i| i as f32 - 0.5),
                ];
                adam.step(&mut params, &grads);
            }
            params
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            for (p, q) in x.data.iter().zip(&y.data) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }

    #[test]
    fn state_tensor_roundtrip() {
        let names = vec!["a.w".to_string(), "a.b".to_string()];
        let params = vec![mat(2, 2, |i| i as f32), mat(1, 2, |_| 0.5)];
        let mut adam = Adam::new(AdamConfig::default(), &params);
        adam.steps = 17;
        adam.m[0].data[3] = -1.25;
        adam.v[1].data[0] = 9.0;
        let tensors = state_to_tensors(&names, &params, &adam);
        assert_eq!(tensors.len(), 7);
        assert_eq!(tensors[0].0, "param.a.w");
        assert_eq!(tensors[2].0, "adam_m.a.w");
        assert_eq!(tensors[6].0, "step");
        let (p2, m2, v2, steps) = state_from_tensors(&names, &params, &tensors).unwrap();
        assert_eq!(steps, 17);
        assert_eq!(p2[0].data, params[0].data);
        assert_eq!(m2[0].data[3], -1.25);
        assert_eq!(v2[1].data[0], 9.0);
    }

    #[test]
    fn state_from_tensors_rejects_mismatches() {
        let names = vec!["w".to_string()];
        let params = vec![mat(2, 2, |_| 0.0)];
        let adam = Adam::new(AdamConfig::default(), &params);
        let good = state_to_tensors(&names, &params, &adam);
        // Wrong count.
        assert!(state_from_tensors(&names, &params, &good[..3]).is_err());
        // Wrong name.
        let mut bad = good.clone();
        bad[0].0 = "param.other".to_string();
        assert!(state_from_tensors(&names, &params, &bad).is_err());
        // Wrong shape.
        let mut bad = good.clone();
        bad[1].1 = HostTensor::F32(vec![1, 4], vec![0.0; 4]);
        assert!(state_from_tensors(&names, &params, &bad).is_err());
        // Missing step.
        let mut bad = good;
        bad[3] = ("notstep".to_string(), HostTensor::I64(vec![], vec![0]));
        assert!(state_from_tensors(&names, &params, &bad).is_err());
    }

    #[test]
    fn adam_config_from_train_json() {
        let cfg = Json::parse(
            r#"{"train": {"learning_rate": 0.01, "weight_decay": 0.1,
                 "adam_beta1": 0.8, "adam_beta2": 0.9, "adam_eps": 1e-6,
                 "num_classes": 4}}"#,
        )
        .unwrap();
        let a = AdamConfig::from_train_config(&cfg).unwrap();
        assert!((a.lr - 0.01).abs() < 1e-9);
        assert!((a.weight_decay - 0.1).abs() < 1e-9);
        assert!((a.beta1 - 0.8).abs() < 1e-9);
        assert!((a.beta2 - 0.9).abs() < 1e-9);
        assert!((a.eps - 1e-6).abs() < 1e-12);
    }
}
