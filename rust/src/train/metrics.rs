//! Masked metric accumulation over an epoch.

use super::StepMetrics;

/// Per-task metric accumulators, folded across steps alongside the
/// loss. Every field is a *sum*; divide by [`TaskMetrics::scored`] for
/// the mean. Which fields a task fills depends on its objective:
///
/// * root classification — `correct` (also mirrored into
///   [`StepMetrics::correct`] for the legacy accuracy path);
/// * link prediction — `correct` (rank-1 hits), `rr_sum` (reciprocal
///   ranks → MRR), `hits_sum` (hits@k);
/// * graph regression — `se_sum` (squared error → MSE), `ae_sum`
///   (absolute error → MAE).
/// All sums are f64, like [`EpochMetrics`]'s — f32 accumulators stop
/// advancing near 2^24 added examples, which a large link-prediction
/// holdout can reach within one epoch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TaskMetrics {
    /// Σ correct predictions (classification / rank-1 link hits).
    pub correct: f64,
    /// Σ reciprocal rank of the positive candidate (link prediction).
    pub rr_sum: f64,
    /// Σ 1[rank ≤ k] (link prediction hits@k).
    pub hits_sum: f64,
    /// Σ squared error (regression).
    pub se_sum: f64,
    /// Σ absolute error (regression).
    pub ae_sum: f64,
    /// Number of scored examples the sums run over.
    pub scored: f64,
}

impl TaskMetrics {
    /// Fold another accumulator in (replica-order summation in the
    /// trainer's all-reduce).
    pub fn merge(&mut self, o: &TaskMetrics) {
        self.correct += o.correct;
        self.rr_sum += o.rr_sum;
        self.hits_sum += o.hits_sum;
        self.se_sum += o.se_sum;
        self.ae_sum += o.ae_sum;
        self.scored += o.scored;
    }
}

/// Accumulates weighted loss and per-task metrics across steps.
#[derive(Debug, Default, Clone)]
pub struct EpochMetrics {
    pub steps: usize,
    pub loss_sum: f64,
    pub correct: f64,
    pub weight: f64,
    /// Per-task metric sums (see [`TaskMetrics`]).
    pub task: TaskMetrics,
}

impl EpochMetrics {
    pub fn add(&mut self, m: StepMetrics) {
        self.steps += 1;
        // A fully masked step reports weight 0 and its mean loss may be
        // NaN (0/0 on the device side); folding `NaN * 0` into the sums
        // would poison the whole epoch, so zero-weight steps count only
        // as a step.
        if m.weight > 0.0 {
            self.loss_sum += m.loss as f64 * m.weight as f64;
            self.correct += m.correct as f64;
            self.weight += m.weight as f64;
            self.task.merge(&m.task);
        }
    }

    /// Example-weighted mean loss.
    pub fn loss(&self) -> f64 {
        if self.weight > 0.0 {
            self.loss_sum / self.weight
        } else {
            0.0
        }
    }

    /// Accuracy over real (unmasked) roots.
    pub fn accuracy(&self) -> f64 {
        if self.weight > 0.0 {
            self.correct / self.weight
        } else {
            0.0
        }
    }

    /// Mean reciprocal rank over scored link-prediction examples.
    pub fn mrr(&self) -> f64 {
        if self.task.scored > 0.0 {
            self.task.rr_sum / self.task.scored
        } else {
            0.0
        }
    }

    /// Hits@k over scored link-prediction examples.
    pub fn hits_at_k(&self) -> f64 {
        if self.task.scored > 0.0 {
            self.task.hits_sum / self.task.scored
        } else {
            0.0
        }
    }

    /// Mean squared error over scored regression examples.
    pub fn mse(&self) -> f64 {
        if self.task.scored > 0.0 {
            self.task.se_sum / self.task.scored
        } else {
            0.0
        }
    }

    /// Mean absolute error over scored regression examples.
    pub fn mae(&self) -> f64 {
        if self.task.scored > 0.0 {
            self.task.ae_sum / self.task.scored
        } else {
            0.0
        }
    }

    /// Number of real examples seen.
    pub fn examples(&self) -> usize {
        self.weight as usize
    }
}

impl std::fmt::Display for EpochMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "loss {:.4} acc {:.4} ({} examples, {} steps)",
            self.loss(),
            self.accuracy(),
            self.examples(),
            self.steps
        )?;
        // Task-specific tails: only print metric families a task
        // actually accumulated (rank metrics for link prediction,
        // error metrics for regression).
        if self.task.rr_sum > 0.0 {
            write!(f, " mrr {:.4} hits@k {:.4}", self.mrr(), self.hits_at_k())?;
        }
        if self.task.se_sum > 0.0 {
            write!(f, " mse {:.4} mae {:.4}", self.mse(), self.mae())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(loss: f32, correct: f32, weight: f32) -> StepMetrics {
        StepMetrics { loss, correct, weight, task: TaskMetrics::default() }
    }

    #[test]
    fn weighted_accumulation() {
        let mut m = EpochMetrics::default();
        m.add(step(1.0, 4.0, 8.0));
        m.add(step(3.0, 2.0, 4.0));
        assert_eq!(m.steps, 2);
        assert!((m.loss() - (1.0 * 8.0 + 3.0 * 4.0) / 12.0).abs() < 1e-9);
        assert!((m.accuracy() - 0.5).abs() < 1e-9);
        assert_eq!(m.examples(), 12);
    }

    #[test]
    fn empty_is_zero() {
        let m = EpochMetrics::default();
        assert_eq!(m.loss(), 0.0);
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.mrr(), 0.0);
        assert_eq!(m.mse(), 0.0);
    }

    /// Regression: an empty/all-masked step (weight 0, loss possibly
    /// NaN from a device-side 0/0) must neither make the aggregates NaN
    /// nor divide by zero — loss()/accuracy() return 0.0, and later
    /// real steps still aggregate correctly.
    #[test]
    fn zero_weight_step_does_not_poison_epoch() {
        let mut m = EpochMetrics::default();
        m.add(step(f32::NAN, 0.0, 0.0));
        assert_eq!(m.steps, 1);
        assert_eq!(m.loss(), 0.0, "no NaN, no division by zero");
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.examples(), 0);
        m.add(step(2.0, 3.0, 4.0));
        assert!(m.loss().is_finite());
        assert!((m.loss() - 2.0).abs() < 1e-9);
        assert!((m.accuracy() - 0.75).abs() < 1e-9);
        // An all-masked *epoch* (only zero-weight steps) is all zeros.
        let mut e = EpochMetrics::default();
        for _ in 0..3 {
            e.add(step(f32::NAN, 0.0, 0.0));
        }
        assert_eq!(e.loss(), 0.0);
        assert_eq!(e.accuracy(), 0.0);
    }

    /// Task metric sums fold across steps and surface as means; the
    /// Display tail appears only for the metric families in use.
    #[test]
    fn task_metrics_accumulate_and_format() {
        let mut m = EpochMetrics::default();
        m.add(StepMetrics {
            loss: 1.0,
            correct: 1.0,
            weight: 2.0,
            task: TaskMetrics {
                correct: 1.0,
                rr_sum: 1.5,
                hits_sum: 2.0,
                scored: 2.0,
                ..TaskMetrics::default()
            },
        });
        m.add(StepMetrics {
            loss: 1.0,
            correct: 0.0,
            weight: 2.0,
            task: TaskMetrics {
                rr_sum: 0.5,
                hits_sum: 0.0,
                scored: 2.0,
                ..TaskMetrics::default()
            },
        });
        assert!((m.mrr() - 0.5).abs() < 1e-9);
        assert!((m.hits_at_k() - 0.5).abs() < 1e-9);
        let text = m.to_string();
        assert!(text.contains("mrr"), "{text}");
        assert!(!text.contains("mse"), "{text}");

        let mut r = EpochMetrics::default();
        r.add(StepMetrics {
            loss: 0.25,
            correct: 0.0,
            weight: 1.0,
            task: TaskMetrics { se_sum: 0.25, ae_sum: 0.5, scored: 1.0, ..TaskMetrics::default() },
        });
        assert!((r.mse() - 0.25).abs() < 1e-9);
        assert!((r.mae() - 0.5).abs() < 1e-9);
        let text = r.to_string();
        assert!(text.contains("mse"), "{text}");
        assert!(!text.contains("mrr"), "{text}");
    }

    /// A zero-weight step must not fold its task sums either.
    #[test]
    fn zero_weight_step_skips_task_sums() {
        let mut m = EpochMetrics::default();
        m.add(StepMetrics {
            loss: f32::NAN,
            correct: 0.0,
            weight: 0.0,
            task: TaskMetrics { rr_sum: 9.0, scored: 9.0, ..TaskMetrics::default() },
        });
        assert_eq!(m.task.scored, 0.0);
        assert_eq!(m.mrr(), 0.0);
    }
}
