//! Masked metric accumulation over an epoch.

use super::StepMetrics;

/// Accumulates weighted loss and accuracy across steps.
#[derive(Debug, Default, Clone)]
pub struct EpochMetrics {
    pub steps: usize,
    pub loss_sum: f64,
    pub correct: f64,
    pub weight: f64,
}

impl EpochMetrics {
    pub fn add(&mut self, m: StepMetrics) {
        self.steps += 1;
        // A fully masked step reports weight 0 and its mean loss may be
        // NaN (0/0 on the device side); folding `NaN * 0` into the sums
        // would poison the whole epoch, so zero-weight steps count only
        // as a step.
        if m.weight > 0.0 {
            self.loss_sum += m.loss as f64 * m.weight as f64;
            self.correct += m.correct as f64;
            self.weight += m.weight as f64;
        }
    }

    /// Example-weighted mean loss.
    pub fn loss(&self) -> f64 {
        if self.weight > 0.0 {
            self.loss_sum / self.weight
        } else {
            0.0
        }
    }

    /// Accuracy over real (unmasked) roots.
    pub fn accuracy(&self) -> f64 {
        if self.weight > 0.0 {
            self.correct / self.weight
        } else {
            0.0
        }
    }

    /// Number of real examples seen.
    pub fn examples(&self) -> usize {
        self.weight as usize
    }
}

impl std::fmt::Display for EpochMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "loss {:.4} acc {:.4} ({} examples, {} steps)",
            self.loss(),
            self.accuracy(),
            self.examples(),
            self.steps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_accumulation() {
        let mut m = EpochMetrics::default();
        m.add(StepMetrics { loss: 1.0, correct: 4.0, weight: 8.0 });
        m.add(StepMetrics { loss: 3.0, correct: 2.0, weight: 4.0 });
        assert_eq!(m.steps, 2);
        assert!((m.loss() - (1.0 * 8.0 + 3.0 * 4.0) / 12.0).abs() < 1e-9);
        assert!((m.accuracy() - 0.5).abs() < 1e-9);
        assert_eq!(m.examples(), 12);
    }

    #[test]
    fn empty_is_zero() {
        let m = EpochMetrics::default();
        assert_eq!(m.loss(), 0.0);
        assert_eq!(m.accuracy(), 0.0);
    }

    /// Regression: an empty/all-masked step (weight 0, loss possibly
    /// NaN from a device-side 0/0) must neither make the aggregates NaN
    /// nor divide by zero — loss()/accuracy() return 0.0, and later
    /// real steps still aggregate correctly.
    #[test]
    fn zero_weight_step_does_not_poison_epoch() {
        let mut m = EpochMetrics::default();
        m.add(StepMetrics { loss: f32::NAN, correct: 0.0, weight: 0.0 });
        assert_eq!(m.steps, 1);
        assert_eq!(m.loss(), 0.0, "no NaN, no division by zero");
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.examples(), 0);
        m.add(StepMetrics { loss: 2.0, correct: 3.0, weight: 4.0 });
        assert!(m.loss().is_finite());
        assert!((m.loss() - 2.0).abs() < 1e-9);
        assert!((m.accuracy() - 0.75).abs() < 1e-9);
        // An all-masked *epoch* (only zero-weight steps) is all zeros.
        let mut e = EpochMetrics::default();
        for _ in 0..3 {
            e.add(StepMetrics { loss: f32::NAN, correct: 0.0, weight: 0.0 });
        }
        assert_eq!(e.loss(), 0.0);
        assert_eq!(e.accuracy(), 0.0);
    }
}
