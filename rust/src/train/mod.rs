//! Training on the AOT train-step (paper §6.2).
//!
//! [`Trainer`] owns the compiled `init`/`train_step`/`eval_step`
//! programs and the **device-resident** model state: params, Adam
//! moments and the step counter stay as PJRT buffers between steps;
//! each step uploads only the batch tensors and downloads only the
//! three scalar metrics. Hyper-parameters (`hp.*` slots) are runtime
//! scalars so the sweep harness varies them per run.
//!
//! [`metrics::EpochMetrics`] accumulates masked loss/accuracy;
//! [`checkpoint`] saves/restores params with the same binary codec as
//! graph records (SavedModel stand-in, §6.2.2).

pub mod checkpoint;
pub mod metrics;
pub mod native;

use std::path::Path;

use crate::graph::pad::Padded;
use crate::runtime::batch::{build_batch, is_batch_slot, RootTask};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::{host_to_literal, HostTensor, Program, Runtime};
use crate::{Error, Result};

/// Runtime hyper-parameters (the A.6.3 search space's continuous part).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyperparams {
    pub learning_rate: f32,
    pub dropout: f32,
    pub weight_decay: f32,
}

impl Hyperparams {
    pub fn from_manifest(m: &crate::runtime::manifest::Manifest) -> Result<Hyperparams> {
        let t = m.config.get("train")?;
        Ok(Hyperparams {
            learning_rate: t.get("learning_rate")?.as_f64()? as f32,
            dropout: m.config.get("model")?.get("dropout")?.as_f64()? as f32,
            weight_decay: t.get("weight_decay")?.as_f64()? as f32,
        })
    }
}

/// Scalar metrics from one train/eval step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
    pub weight: f32,
    /// Per-task metric sums for this step (MRR/hits for link
    /// prediction, squared error for regression, correct count for
    /// classification) — see [`metrics::TaskMetrics`].
    pub task: metrics::TaskMetrics,
}

/// The trainer: compiled programs + model/optimizer state.
pub struct Trainer {
    pub rt: Runtime,
    pub entry: ModelEntry,
    init_prog: Program,
    train_prog: Program,
    eval_prog: Program,
    /// Model state as literals: params ++ adam_m ++ adam_v ++ [step].
    /// (PJRT in this crate returns a single tuple buffer per execution
    /// with no buffer-level untuple, so state round-trips as literals —
    /// a host memcpy per step on the CPU client; see §Perf.)
    state: Vec<xla::Literal>,
    /// Number of param leaves.
    n_params: usize,
    pub task: RootTask,
    pub hp: Hyperparams,
    pub steps_done: u64,
}

impl Trainer {
    /// Load programs, run `init`, set up state.
    pub fn new(
        rt: Runtime,
        artifacts_dir: &Path,
        entry: &ModelEntry,
        task: RootTask,
        hp: Hyperparams,
    ) -> Result<Trainer> {
        let init_prog = rt.load_program(artifacts_dir, entry.program("init")?)?;
        let train_prog = rt.load_program(artifacts_dir, entry.program("train_step")?)?;
        let eval_prog = rt.load_program(artifacts_dir, entry.program("eval_step")?)?;

        // The trainer feeds state positionally: train_step's leading
        // inputs must be params ++ adam_m ++ adam_v ++ step, unpruned.
        // (jax only prunes dead args; in train_step every param feeds
        // its own Adam update, so this holds for any arch — assert it
        // loudly in case a future model breaks the invariant.)
        let n = init_prog.spec.outputs.len();
        for (i, slot) in train_prog.spec.inputs.iter().take(3 * n + 1).enumerate() {
            let want_prefix = match i {
                k if k < n => "param.",
                k if k < 2 * n => "adam_m.",
                k if k < 3 * n => "adam_v.",
                _ => "step",
            };
            if !slot.name.starts_with(want_prefix) {
                return Err(Error::Runtime(format!(
                    "train_step slot {i} is {:?}, expected prefix {want_prefix:?} — \
                     state layout was pruned; regenerate artifacts",
                    slot.name
                )));
            }
        }
        let params = init_prog.execute_literals(&[])?;
        let n_params = init_prog.spec.outputs.len();
        if params.len() != n_params {
            return Err(Error::Runtime(format!(
                "init produced {} literals for {} params",
                params.len(),
                n_params
            )));
        }
        // Zero Adam state mirrors each param's shape.
        let mut state = Vec::with_capacity(3 * n_params + 1);
        for p in params {
            state.push(p);
        }
        for _slot in 0..2 {
            for i in 0..n_params {
                let spec = &init_prog.spec.outputs[i];
                let zeros = HostTensor::F32(spec.shape.clone(), vec![0.0; spec.elems()]);
                state.push(host_to_literal(&zeros)?);
            }
        }
        state.push(host_to_literal(&HostTensor::I32(vec![], vec![0]))?);
        Ok(Trainer {
            rt,
            entry: entry.clone(),
            init_prog,
            train_prog,
            eval_prog,
            state,
            n_params,
            task,
            hp,
            steps_done: 0,
        })
    }

    /// Re-initialize params and optimizer state without recompiling the
    /// programs — the sweep harness runs one trial per reset (compiling
    /// the train-step HLO dominates trial cost otherwise; see §Perf).
    pub fn reset(&mut self) -> Result<()> {
        let params = self.init_prog.execute_literals(&[])?;
        let mut state = Vec::with_capacity(3 * self.n_params + 1);
        for p in params {
            state.push(p);
        }
        for _slot in 0..2 {
            for i in 0..self.n_params {
                let spec = &self.init_prog.spec.outputs[i];
                let zeros = HostTensor::F32(spec.shape.clone(), vec![0.0; spec.elems()]);
                state.push(host_to_literal(&zeros)?);
            }
        }
        state.push(host_to_literal(&HostTensor::I32(vec![], vec![0]))?);
        self.state = state;
        self.steps_done = 0;
        Ok(())
    }

    /// Execute one training step on a padded batch.
    pub fn train_batch(&mut self, padded: &Padded) -> Result<StepMetrics> {
        let inputs = &self.train_prog.spec.inputs;
        let n_state = 3 * self.n_params + 1;
        let hp_lr = host_to_literal(&HostTensor::F32(vec![], vec![self.hp.learning_rate]))?;
        let hp_do = host_to_literal(&HostTensor::F32(vec![], vec![self.hp.dropout]))?;
        let hp_wd = host_to_literal(&HostTensor::F32(vec![], vec![self.hp.weight_decay]))?;
        let batch = build_batch(padded, &self.task, inputs)?;
        let mut batch_lits = Vec::with_capacity(batch.len());
        for (idx, t) in &batch {
            if !t.matches(&inputs[*idx]) {
                return Err(Error::Runtime(format!(
                    "batch slot {} mismatch: built {}{:?}, manifest {}{:?}",
                    inputs[*idx].name,
                    t.dtype_name(),
                    t.shape(),
                    inputs[*idx].dtype,
                    inputs[*idx].shape,
                )));
            }
            batch_lits.push((*idx, host_to_literal(t)?));
        }

        // Assemble argument list in manifest order.
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        let mut batch_iter = batch_lits.iter().peekable();
        for (i, spec) in inputs.iter().enumerate() {
            if i < n_state {
                args.push(&self.state[i]);
            } else if spec.name == "hp.learning_rate" {
                args.push(&hp_lr);
            } else if spec.name == "hp.dropout" {
                args.push(&hp_do);
            } else if spec.name == "hp.weight_decay" {
                args.push(&hp_wd);
            } else if is_batch_slot(&spec.name) {
                let (idx, lit) = batch_iter
                    .next()
                    .ok_or_else(|| Error::Runtime("batch slots exhausted".into()))?;
                if *idx != i {
                    return Err(Error::Runtime(format!(
                        "batch slot order mismatch at {} ({})",
                        i, spec.name
                    )));
                }
                args.push(lit);
            } else {
                return Err(Error::Runtime(format!("unhandled input slot {:?}", spec.name)));
            }
        }

        let mut outputs = self.train_prog.execute_literals(&args)?;
        // Outputs: params ++ m ++ v ++ step ++ (loss, correct, weight).
        let weight = scalar_f32(&outputs[n_state + 2])?;
        let correct = scalar_f32(&outputs[n_state + 1])?;
        let loss = scalar_f32(&outputs[n_state])?;
        outputs.truncate(n_state);
        self.state = outputs;
        self.steps_done += 1;
        Ok(StepMetrics {
            loss,
            correct,
            weight,
            task: metrics::TaskMetrics {
                correct: correct as f64,
                scored: weight as f64,
                ..Default::default()
            },
        })
    }

    /// Evaluate one padded batch (no state change).
    ///
    /// Eval/forward artifacts may have a *pruned* signature (jax drops
    /// dead arguments, e.g. the last layer's author-side weights), so
    /// param slots are resolved by name against the train-step layout.
    pub fn eval_batch(&self, padded: &Padded) -> Result<StepMetrics> {
        let inputs = &self.eval_prog.spec.inputs;
        let batch = build_batch(padded, &self.task, inputs)?;
        let mut batch_lits = Vec::with_capacity(batch.len());
        for (idx, t) in &batch {
            batch_lits.push((*idx, host_to_literal(t)?));
        }
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        let mut batch_iter = batch_lits.iter();
        for (i, spec) in inputs.iter().enumerate() {
            if let Some(name) = spec.name.strip_prefix("param.") {
                args.push(&self.state[self.param_slot(name)?]);
            } else if is_batch_slot(&spec.name) {
                let (idx, lit) = batch_iter
                    .next()
                    .ok_or_else(|| Error::Runtime("batch slots exhausted".into()))?;
                if *idx != i {
                    return Err(Error::Runtime("eval batch slot order mismatch".into()));
                }
                args.push(lit);
            } else {
                return Err(Error::Runtime(format!("unhandled eval slot {:?}", spec.name)));
            }
        }
        let outputs = self.eval_prog.execute_literals(&args)?;
        let correct = scalar_f32(&outputs[1])?;
        let weight = scalar_f32(&outputs[2])?;
        Ok(StepMetrics {
            loss: scalar_f32(&outputs[0])?,
            correct,
            weight,
            task: metrics::TaskMetrics {
                correct: correct as f64,
                scored: weight as f64,
                ..Default::default()
            },
        })
    }

    /// Download current params (name → tensor), e.g. for checkpointing.
    pub fn params_to_host(&self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::with_capacity(self.n_params);
        for i in 0..self.n_params {
            let spec = &self.train_prog.spec.inputs[i];
            out.push((spec.name.clone(), crate::runtime::literal_to_host(&self.state[i])?));
        }
        Ok(out)
    }

    /// Restore params from host tensors (checkpoint load). Adam state
    /// and step are reset.
    pub fn params_from_host(&mut self, params: &[(String, HostTensor)]) -> Result<()> {
        if params.len() != self.n_params {
            return Err(Error::Runtime(format!(
                "checkpoint has {} params, model wants {}",
                params.len(),
                self.n_params
            )));
        }
        for (i, (name, t)) in params.iter().enumerate() {
            let spec = &self.train_prog.spec.inputs[i];
            if &spec.name != name || !t.matches(spec) {
                return Err(Error::Runtime(format!(
                    "checkpoint param {i} ({name}) does not match manifest slot {}",
                    spec.name
                )));
            }
            self.state[i] = host_to_literal(t)?;
        }
        Ok(())
    }

    pub fn param_count(&self) -> usize {
        self.entry.param_count
    }

    /// State index of a named param (train-step layout).
    fn param_slot(&self, name: &str) -> Result<usize> {
        let want = format!("param.{name}");
        self.train_prog
            .spec
            .inputs[..self.n_params]
            .iter()
            .position(|t| t.name == want)
            .ok_or_else(|| Error::Runtime(format!("no param slot {want:?}")))
    }
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    match crate::runtime::literal_to_host(lit)? {
        HostTensor::F32(_, v) if v.len() == 1 => Ok(v[0]),
        other => Err(Error::Runtime(format!(
            "expected scalar f32, got {}{:?}",
            other.dtype_name(),
            other.shape()
        ))),
    }
}
