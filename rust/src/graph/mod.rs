//! `GraphTensor` — the heterogeneous graph container (paper §3.2).
//!
//! A [`GraphTensor`] holds, per node set and edge set, a dictionary of
//! features plus (for edge sets) the source/target index tensors, and a
//! per-component size vector. A freshly parsed input graph has one
//! *component*; [`batch::merge`] concatenates a batch of graphs into a
//! single scalar GraphTensor whose components are the original inputs,
//! with edge indices shifted into the flat index space — exactly the
//! `merge_batch_to_components` semantics of TF-GNN.
//!
//! [`pad`] implements the fixed-size padding TF-GNN uses for TPUs
//! (§3.2, §8.4): every batch is padded to a static [`pad::PadSpec`] so a
//! single AOT-compiled HLO program can consume every batch.
//!
//! [`io`] provides the on-disk record format standing in for
//! `tf.train.Example` + TFRecord shards.

pub mod batch;
pub mod csr;
pub mod io;
pub mod pad;
mod tensor;

pub use csr::{Csr, Incidence};
pub use tensor::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
