//! Cached CSR (compressed sparse row) views of edge-set adjacency.
//!
//! The data-exchange ops of §4.1 are COO-oriented: an edge set stores
//! parallel `source`/`target` index arrays, and a broadcast→pool
//! round-trip walks them twice while materializing a full
//! `[num_edges, d]` intermediate. The fused fast path (`ops::fused`)
//! instead walks a *per-receiver* view: for each node, the ids of its
//! incident edges plus the node at the opposite endpoint. That view is
//! exactly a CSR adjacency, and it only depends on the (immutable)
//! adjacency arrays — so it is built lazily on first use and memoized
//! on the [`EdgeSet`](super::EdgeSet) itself, surviving feature
//! engineering, multiple model layers, and repeated serving requests
//! over the same graph.
//!
//! Building the view also validates both endpoint arrays against their
//! node-set sizes, turning corrupt adjacency into a proper
//! [`Error::Graph`] instead of a slice panic deep inside a kernel.
//!
//! Construction is a stable counting sort over edge ids, so within
//! each receiver row the edge ids are ascending. The fused kernels
//! rely on this: accumulating a row in ascending edge order performs
//! float additions in exactly the order the unfused
//! `segment_sum`-style oracle does, keeping the two paths bit-for-bit
//! identical.

use std::fmt;
use std::sync::{Arc, OnceLock};

use crate::{Error, Result};

/// Which endpoint the rows of a CSR view are keyed by (the *receiver*
/// of a pool, mirroring `ops::Tag`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Incidence {
    BySource,
    ByTarget,
}

/// A per-node view of one edge set's adjacency.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    /// Row boundaries: node `v`'s incident edges live at
    /// `edges[offsets[v]..offsets[v+1]]`. Length `num_nodes + 1`.
    pub offsets: Vec<usize>,
    /// Edge ids grouped by incident node, ascending within each row.
    pub edges: Vec<u32>,
    /// For `edges[k]`, the node at the *opposite* endpoint.
    pub neighbors: Vec<u32>,
}

impl Csr {
    /// Number of nodes (rows).
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Edge ids incident to node `v`.
    pub fn row(&self, v: usize) -> &[u32] {
        &self.edges[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Opposite-endpoint node ids for node `v`'s incident edges.
    pub fn row_neighbors(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Build a CSR view keyed by `keyed` (length-checked elsewhere;
    /// `keyed` and `opposite` are the two parallel COO index arrays).
    ///
    /// Validates every index: `keyed[e] < n_keyed` and
    /// `opposite[e] < n_opposite`, reporting the offending edge.
    pub fn build(
        edge_set: &str,
        keyed: &[u32],
        opposite: &[u32],
        n_keyed: usize,
        n_opposite: usize,
    ) -> Result<Csr> {
        debug_assert_eq!(keyed.len(), opposite.len());
        let mut counts = vec![0usize; n_keyed + 1];
        for (e, &v) in keyed.iter().enumerate() {
            if v as usize >= n_keyed {
                return Err(Error::Graph(format!(
                    "edge set {edge_set:?}: edge {e} references node {v} \
                     but the keyed node set has {n_keyed} nodes"
                )));
            }
            counts[v as usize + 1] += 1;
        }
        for (e, &v) in opposite.iter().enumerate() {
            if v as usize >= n_opposite {
                return Err(Error::Graph(format!(
                    "edge set {edge_set:?}: edge {e} references node {v} \
                     but the opposite node set has {n_opposite} nodes"
                )));
            }
        }
        // Prefix sums -> row offsets.
        let mut offsets = counts;
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        // Stable scatter: edge ids ascending within each row.
        let mut cursor = offsets.clone();
        let mut edges = vec![0u32; keyed.len()];
        let mut neighbors = vec![0u32; keyed.len()];
        for (e, (&v, &u)) in keyed.iter().zip(opposite).enumerate() {
            let at = cursor[v as usize];
            edges[at] = e as u32;
            neighbors[at] = u;
            cursor[v as usize] = at + 1;
        }
        Ok(Csr { offsets, edges, neighbors })
    }
}

/// Lazily-built, memoized CSR views for one edge set (one per
/// incidence direction).
///
/// Lives on [`EdgeSet`](super::EdgeSet) but is deliberately invisible
/// to its derived semantics: clones carry already-built views (they
/// are immutable and shared via `Arc`), equality ignores the cache,
/// and (de)serialization skips it.
pub struct CsrCache {
    by_source: OnceLock<Arc<Csr>>,
    by_target: OnceLock<Arc<Csr>>,
}

impl CsrCache {
    pub fn new() -> CsrCache {
        CsrCache { by_source: OnceLock::new(), by_target: OnceLock::new() }
    }

    /// The memoized view for `inc`, building it on first use via
    /// `build` (which receives the incidence to construct).
    pub fn get_or_build(
        &self,
        inc: Incidence,
        build: impl FnOnce() -> Result<Csr>,
    ) -> Result<Arc<Csr>> {
        let slot = match inc {
            Incidence::BySource => &self.by_source,
            Incidence::ByTarget => &self.by_target,
        };
        if let Some(csr) = slot.get() {
            return Ok(Arc::clone(csr));
        }
        // Not cached: build outside the lock; a racing builder's value
        // simply loses the `set` and is dropped (same contents anyway).
        let built = Arc::new(build()?);
        let _ = slot.set(Arc::clone(&built));
        Ok(Arc::clone(slot.get().unwrap_or(&built)))
    }

    /// Whether a view is already built (used by tests to assert
    /// memoization without timing).
    pub fn is_built(&self, inc: Incidence) -> bool {
        match inc {
            Incidence::BySource => self.by_source.get().is_some(),
            Incidence::ByTarget => self.by_target.get().is_some(),
        }
    }
}

impl Default for CsrCache {
    fn default() -> Self {
        CsrCache::new()
    }
}

impl Clone for CsrCache {
    fn clone(&self) -> Self {
        let c = CsrCache::new();
        if let Some(v) = self.by_source.get() {
            let _ = c.by_source.set(Arc::clone(v));
        }
        if let Some(v) = self.by_target.get() {
            let _ = c.by_target.set(Arc::clone(v));
        }
        c
    }
}

/// The cache is derived state: two edge sets are equal iff their real
/// contents are, regardless of which views happen to be built.
impl PartialEq for CsrCache {
    fn eq(&self, _other: &Self) -> bool {
        true
    }
}

impl fmt::Debug for CsrCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "CsrCache {{ by_source: {}, by_target: {} }}",
            if self.by_source.get().is_some() { "built" } else { "-" },
            if self.by_target.get().is_some() { "built" } else { "-" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_groups_and_sorts_edge_ids() {
        // Edges (source -> target): 0:2->0, 1:0->1, 2:2->1, 3:1->0
        let source = [2u32, 0, 2, 1];
        let target = [0u32, 1, 1, 0];
        let csr = Csr::build("e", &target, &source, 2, 3).unwrap();
        assert_eq!(csr.num_nodes(), 2);
        assert_eq!(csr.num_edges(), 4);
        assert_eq!(csr.row(0), &[0, 3]); // edges into target 0, ascending
        assert_eq!(csr.row(1), &[1, 2]);
        assert_eq!(csr.row_neighbors(0), &[2, 1]); // their sources
        assert_eq!(csr.row_neighbors(1), &[0, 2]);
    }

    #[test]
    fn build_handles_isolated_nodes() {
        let csr = Csr::build("e", &[], &[], 3, 3).unwrap();
        assert_eq!(csr.num_nodes(), 3);
        for v in 0..3 {
            assert!(csr.row(v).is_empty());
        }
    }

    #[test]
    fn build_rejects_out_of_range_indices() {
        let err = Csr::build("e", &[5], &[0], 2, 2).unwrap_err().to_string();
        assert!(err.contains("graph error"), "{err}");
        assert!(err.contains("edge 0"), "{err}");
        let err = Csr::build("e", &[1], &[9], 2, 2).unwrap_err().to_string();
        assert!(err.contains("opposite"), "{err}");
    }

    #[test]
    fn cache_memoizes_and_clone_shares() {
        let cache = CsrCache::new();
        assert!(!cache.is_built(Incidence::ByTarget));
        let a = cache
            .get_or_build(Incidence::ByTarget, || Csr::build("e", &[0, 1], &[1, 0], 2, 2))
            .unwrap();
        assert!(cache.is_built(Incidence::ByTarget));
        assert!(!cache.is_built(Incidence::BySource));
        let b = cache
            .get_or_build(Incidence::ByTarget, || panic!("must be memoized"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup reuses the built view");
        let cloned = cache.clone();
        assert!(cloned.is_built(Incidence::ByTarget), "clones inherit built views");
    }

    #[test]
    fn cache_is_invisible_to_equality() {
        let a = CsrCache::new();
        let b = CsrCache::new();
        let _ = a.get_or_build(Incidence::BySource, || Csr::build("e", &[0], &[0], 1, 1));
        assert_eq!(a, b);
    }
}
