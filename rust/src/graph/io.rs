//! On-disk GraphTensor records (the `tf.train.Example` + TFRecord
//! substitute — see DESIGN.md §Substitutions).
//!
//! Layout, little-endian throughout:
//!
//! ```text
//! shard file  := magic "GTS1" | record*
//! record      := u64 payload_len | u32 checksum(payload) | payload
//! payload     := GraphTensor encoding (see encode_graph)
//! ```
//!
//! The checksum is a FNV-1a/64 folded to 32 bits — enough to catch
//! truncation and corruption, like TFRecord's masked CRC. Shards are
//! named `prefix-00007-of-00032.gts`; [`ShardSet`] enumerates and reads
//! them, which is what the paper's "GraphTensors randomly grouped into
//! file shards" (§6.1.1) feeds into training.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::tensor::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"GTS1";

// ---------------------------------------------------------------------------
// Byte-level encoding helpers
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::with_capacity(4096) }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usize_vec(&mut self, v: &[usize]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x as u64);
        }
    }

    fn u32_vec(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f32_vec(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn i64_vec(&mut self, v: &[i64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, i: 0 }
    }

    fn err(&self, what: &str) -> Error {
        Error::Codec(format!("record decode error at byte {}: {}", self.i, what))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.buf.len() {
            return Err(self.err("truncated"));
        }
        let s = &self.buf[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(arr(self.take(8)?)))
    }

    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // Sanity: a single vector longer than the remaining buffer bytes
        // is corrupt; avoids huge allocations on bad data.
        if n > (self.buf.len() - self.i) as u64 * 8 + 64 {
            return Err(self.err("implausible length"));
        }
        Ok(n as usize)
    }

    fn usize_vec(&mut self) -> Result<Vec<usize>> {
        let n = self.len()?;
        (0..n).map(|_| self.u64().map(|v| v as usize)).collect()
    }

    fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(arr(c))).collect())
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(arr(c))).collect())
    }

    fn i64_vec(&mut self) -> Result<Vec<i64>> {
        let n = self.len()?;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| i64::from_le_bytes(arr(c))).collect())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err("invalid UTF-8"))
    }
}

/// Fixed-size copy of an exact-length chunk. `take`/`chunks_exact`
/// guarantee the length, so no fallible `try_into` is needed.
fn arr<const N: usize>(c: &[u8]) -> [u8; N] {
    let mut a = [0u8; N];
    a.copy_from_slice(c);
    a
}

fn checksum(payload: &[u8]) -> u32 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h ^ (h >> 32)) as u32
}

// ---------------------------------------------------------------------------
// Feature / GraphTensor encoding
// ---------------------------------------------------------------------------

fn encode_feature(e: &mut Enc, f: &Feature) {
    match f {
        Feature::F32 { dims, data } => {
            e.u8(0);
            e.usize_vec(dims);
            e.f32_vec(data);
        }
        Feature::I64 { dims, data } => {
            e.u8(1);
            e.usize_vec(dims);
            e.i64_vec(data);
        }
        Feature::Str { data } => {
            e.u8(2);
            e.u64(data.len() as u64);
            for s in data {
                e.str(s);
            }
        }
        Feature::RaggedF32 { row_splits, data } => {
            e.u8(3);
            e.usize_vec(row_splits);
            e.f32_vec(data);
        }
        Feature::RaggedI64 { row_splits, data } => {
            e.u8(4);
            e.usize_vec(row_splits);
            e.i64_vec(data);
        }
    }
}

fn decode_feature(d: &mut Dec) -> Result<Feature> {
    match d.u8()? {
        0 => Ok(Feature::F32 { dims: d.usize_vec()?, data: d.f32_vec()? }),
        1 => Ok(Feature::I64 { dims: d.usize_vec()?, data: d.i64_vec()? }),
        2 => {
            let n = d.len()?;
            let data = (0..n).map(|_| d.str()).collect::<Result<Vec<_>>>()?;
            Ok(Feature::Str { data })
        }
        3 => Ok(Feature::RaggedF32 { row_splits: d.usize_vec()?, data: d.f32_vec()? }),
        4 => Ok(Feature::RaggedI64 { row_splits: d.usize_vec()?, data: d.i64_vec()? }),
        t => Err(d.err(&format!("unknown feature tag {t}"))),
    }
}

fn encode_features(e: &mut Enc, feats: &BTreeMap<String, Feature>) {
    e.u64(feats.len() as u64);
    for (name, f) in feats {
        e.str(name);
        encode_feature(e, f);
    }
}

fn decode_features(d: &mut Dec) -> Result<BTreeMap<String, Feature>> {
    let n = d.len()?;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name = d.str()?;
        out.insert(name, decode_feature(d)?);
    }
    Ok(out)
}

/// Encode a GraphTensor to bytes.
pub fn encode_graph(g: &GraphTensor) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(g.num_components as u64);
    encode_features(&mut e, &g.context.features);
    e.u64(g.node_sets.len() as u64);
    for (name, ns) in &g.node_sets {
        e.str(name);
        e.usize_vec(&ns.sizes);
        encode_features(&mut e, &ns.features);
    }
    e.u64(g.edge_sets.len() as u64);
    for (name, es) in &g.edge_sets {
        e.str(name);
        e.usize_vec(&es.sizes);
        e.str(&es.adjacency.source_set);
        e.str(&es.adjacency.target_set);
        e.u32_vec(&es.adjacency.source);
        e.u32_vec(&es.adjacency.target);
        encode_features(&mut e, &es.features);
    }
    e.buf
}

/// Decode a GraphTensor from bytes (validates structure).
pub fn decode_graph(bytes: &[u8]) -> Result<GraphTensor> {
    let mut d = Dec::new(bytes);
    let num_components = d.u64()? as usize;
    let context = Context { features: decode_features(&mut d)? };
    let n_ns = d.len()?;
    let mut node_sets = BTreeMap::new();
    for _ in 0..n_ns {
        let name = d.str()?;
        let sizes = d.usize_vec()?;
        let features = decode_features(&mut d)?;
        node_sets.insert(name, NodeSet { sizes, features });
    }
    let n_es = d.len()?;
    let mut edge_sets = BTreeMap::new();
    for _ in 0..n_es {
        let name = d.str()?;
        let sizes = d.usize_vec()?;
        let source_set = d.str()?;
        let target_set = d.str()?;
        let source = d.u32_vec()?;
        let target = d.u32_vec()?;
        let features = decode_features(&mut d)?;
        let mut es =
            EdgeSet::new(sizes, Adjacency { source_set, target_set, source, target });
        es.features = features;
        edge_sets.insert(name, es);
    }
    if d.i != bytes.len() {
        return Err(d.err("trailing bytes"));
    }
    let g = GraphTensor { context, node_sets, edge_sets, num_components };
    g.validate()?;
    Ok(g)
}

// ---------------------------------------------------------------------------
// Shard files
// ---------------------------------------------------------------------------

/// Streaming writer for one shard file.
pub struct ShardWriter {
    w: BufWriter<std::fs::File>,
    pub records: usize,
}

impl ShardWriter {
    pub fn create(path: &Path) -> Result<ShardWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        Ok(ShardWriter { w, records: 0 })
    }

    pub fn write(&mut self, g: &GraphTensor) -> Result<()> {
        let payload = encode_graph(g);
        self.w.write_all(&(payload.len() as u64).to_le_bytes())?;
        self.w.write_all(&checksum(&payload).to_le_bytes())?;
        self.w.write_all(&payload)?;
        self.records += 1;
        Ok(())
    }

    pub fn finish(mut self) -> Result<usize> {
        self.w.flush()?;
        Ok(self.records)
    }
}

/// Streaming reader for one shard file.
pub struct ShardReader {
    r: BufReader<std::fs::File>,
    path: PathBuf,
    /// File size at open and bytes consumed so far — what
    /// [`ShardReader::next`] validates each record's `payload_len`
    /// against before allocating (a corrupted or hostile length field
    /// must not drive `vec![0u8; len]`).
    file_len: u64,
    pos: u64,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<ShardReader> {
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Codec(format!("{}: bad magic", path.display())));
        }
        Ok(ShardReader { r, path: path.to_path_buf(), file_len, pos: MAGIC.len() as u64 })
    }

    /// Read the next record; `Ok(None)` at clean EOF.
    ///
    /// The record's `payload_len` is **untrusted**: it is validated
    /// against the shard's remaining bytes before any allocation, so a
    /// bit-flipped or hostile length field yields a structured
    /// [`Error::Codec`] naming the shard instead of a multi-gigabyte
    /// allocation followed by a confusing short read.
    pub fn next(&mut self) -> Result<Option<GraphTensor>> {
        let mut len_bytes = [0u8; 8];
        match self.r.read_exact(&mut len_bytes) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        self.pos += 8;
        let len = u64::from_le_bytes(len_bytes);
        // 4 bytes of checksum still precede the payload.
        let remaining = self.file_len.saturating_sub(self.pos).saturating_sub(4);
        if len > remaining {
            return Err(Error::Codec(format!(
                "{}: record payload length {len} exceeds the shard's remaining \
                 {remaining} bytes (truncated file, or corrupt/hostile length field)",
                self.path.display()
            )));
        }
        let len = len as usize;
        let mut crc_bytes = [0u8; 4];
        self.r.read_exact(&mut crc_bytes).map_err(|e| self.trunc_err(e))?;
        self.pos += 4;
        let want_crc = u32::from_le_bytes(crc_bytes);
        let mut payload = vec![0u8; len];
        self.r.read_exact(&mut payload).map_err(|e| self.trunc_err(e))?;
        self.pos += len as u64;
        if checksum(&payload) != want_crc {
            return Err(Error::Codec(format!("{}: checksum mismatch", self.path.display())));
        }
        Ok(Some(decode_graph(&payload)?))
    }

    /// A short read mid-record (the length check bounds payloads by the
    /// file size at open, so this fires only if the file shrank
    /// underneath us) — still a structured codec error naming the
    /// shard.
    fn trunc_err(&self, e: std::io::Error) -> Error {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Codec(format!("{}: truncated mid-record", self.path.display()))
        } else {
            Error::Io(e)
        }
    }
}

impl Iterator for ShardReader {
    type Item = Result<GraphTensor>;

    fn next(&mut self) -> Option<Self::Item> {
        ShardReader::next(self).transpose()
    }
}

/// A set of shard files `prefix-XXXXX-of-NNNNN.gts`.
#[derive(Debug, Clone)]
pub struct ShardSet {
    pub paths: Vec<PathBuf>,
}

impl ShardSet {
    /// Shard path for index `i` of `n`.
    pub fn shard_path(dir: &Path, prefix: &str, i: usize, n: usize) -> PathBuf {
        dir.join(format!("{prefix}-{i:05}-of-{n:05}.gts"))
    }

    /// Enumerate existing shards matching a prefix in a directory.
    pub fn discover(dir: &Path, prefix: &str) -> Result<ShardSet> {
        let mut paths = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if name.starts_with(&format!("{prefix}-")) && name.ends_with(".gts") {
                paths.push(p);
            }
        }
        paths.sort();
        if paths.is_empty() {
            return Err(Error::Pipeline(format!(
                "no shards with prefix {prefix:?} under {}",
                dir.display()
            )));
        }
        Ok(ShardSet { paths })
    }

    /// Write `graphs`, distributing round-robin over `n` shards.
    pub fn write_all(
        dir: &Path,
        prefix: &str,
        n: usize,
        graphs: impl Iterator<Item = GraphTensor>,
    ) -> Result<ShardSet> {
        assert!(n > 0);
        let mut writers = (0..n)
            .map(|i| ShardWriter::create(&Self::shard_path(dir, prefix, i, n)))
            .collect::<Result<Vec<_>>>()?;
        for (k, g) in graphs.enumerate() {
            writers[k % n].write(&g)?;
        }
        let mut paths = Vec::new();
        for (i, w) in writers.into_iter().enumerate() {
            w.finish()?;
            paths.push(Self::shard_path(dir, prefix, i, n));
        }
        Ok(ShardSet { paths })
    }

    /// Total record count (reads every shard).
    pub fn count(&self) -> Result<usize> {
        let mut total = 0;
        for p in &self.paths {
            let mut r = ShardReader::open(p)?;
            while r.next()?.is_some() {
                total += 1;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::batch::random_graph;
    use crate::synth::recsys::recsys_example_graph;
    use crate::util::proptest::check;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tfgnn-io-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn encode_decode_recsys() {
        let g = recsys_example_graph().unwrap();
        let bytes = encode_graph(&g);
        let g2 = decode_graph(&bytes).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        check("encode∘decode = id", 60, |rng| {
            let g = random_graph(rng);
            let g2 = decode_graph(&encode_graph(&g)).unwrap();
            assert_eq!(g, g2);
        });
    }

    #[test]
    fn shard_write_read_roundtrip() {
        let dir = tmpdir("rw");
        let g = recsys_example_graph().unwrap();
        let path = dir.join("x.gts");
        let mut w = ShardWriter::create(&path).unwrap();
        for _ in 0..5 {
            w.write(&g).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 5);
        let r = ShardReader::open(&path).unwrap();
        let all: Vec<_> = r.map(|g| g.unwrap()).collect();
        assert_eq!(all.len(), 5);
        assert_eq!(all[3], g);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("corrupt");
        let path = dir.join("x.gts");
        let mut w = ShardWriter::create(&path).unwrap();
        w.write(&recsys_example_graph().unwrap()).unwrap();
        w.finish().unwrap();
        // Flip a byte in the payload area.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.next().is_err(), "checksum must catch corruption");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncation_detected() {
        let dir = tmpdir("trunc");
        let path = dir.join("x.gts");
        let mut w = ShardWriter::create(&path).unwrap();
        w.write(&recsys_example_graph().unwrap()).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.next().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A record truncated in the middle of its payload must surface as
    /// a structured `Error::Codec` naming the shard — the untrusted
    /// `payload_len` now exceeds what the file still holds.
    #[test]
    fn truncated_mid_payload_is_codec_error_naming_shard() {
        let dir = tmpdir("trunc-mid");
        let path = dir.join("x.gts");
        let mut w = ShardWriter::create(&path).unwrap();
        w.write(&recsys_example_graph().unwrap()).unwrap();
        w.write(&recsys_example_graph().unwrap()).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut into the middle of the *second* record's payload: the
        // first record must still read cleanly.
        let first_payload = encode_graph(&recsys_example_graph().unwrap()).len();
        let cut = 4 + 12 + first_payload + 12 + first_payload / 2;
        assert!(cut < bytes.len());
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.next().unwrap().is_some(), "first record intact");
        let err = match r.next() {
            Err(e) => e,
            other => panic!("expected codec error, got {other:?}"),
        };
        let msg = err.to_string();
        assert!(msg.contains("codec"), "{msg}");
        assert!(msg.contains("x.gts"), "error must name the shard: {msg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A bit-flipped length field (here: high byte set, claiming an
    /// exabyte payload) must be rejected *before* allocation, as a
    /// structured `Error::Codec` naming the shard.
    #[test]
    fn bit_flipped_length_is_codec_error_without_allocation() {
        let dir = tmpdir("bad-len");
        let path = dir.join("x.gts");
        let mut w = ShardWriter::create(&path).unwrap();
        w.write(&recsys_example_graph().unwrap()).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The u64 length field sits right after the 4-byte magic;
        // flipping its top byte claims a ~2^60-byte payload. If the
        // reader trusted it, vec![0u8; len] would try to allocate it.
        bytes[4 + 7] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        let err = match r.next() {
            Err(e) => e,
            other => panic!("expected codec error, got {other:?}"),
        };
        let msg = err.to_string();
        assert!(msg.contains("codec"), "{msg}");
        assert!(msg.contains("x.gts"), "error must name the shard: {msg}");
        assert!(msg.contains("length"), "{msg}");

        // A small (but wrong) flipped length lands on the checksum
        // guard instead — also a structured error, not a panic.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4 + 7] ^= 0x10; // restore
        bytes[4] ^= 0x01; // off-by-one length
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.next().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = tmpdir("magic");
        let path = dir.join("x.gts");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shardset_roundrobin_and_discover() {
        let dir = tmpdir("set");
        let g = recsys_example_graph().unwrap();
        let graphs = (0..10).map(|_| g.clone());
        let set = ShardSet::write_all(&dir, "train", 3, graphs).unwrap();
        assert_eq!(set.paths.len(), 3);
        assert_eq!(set.count().unwrap(), 10);
        let found = ShardSet::discover(&dir, "train").unwrap();
        assert_eq!(found.paths, set.paths);
        assert!(ShardSet::discover(&dir, "missing").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_shard_reads_cleanly() {
        let dir = tmpdir("empty");
        let path = dir.join("x.gts");
        let w = ShardWriter::create(&path).unwrap();
        w.finish().unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.next().unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
