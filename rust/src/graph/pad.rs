//! Fixed-size padding (paper §3.2, §8.4).
//!
//! TPUs — and our AOT-compiled HLO programs — need static shapes. TF-GNN
//! achieves this by "adding a suitably sized padding graph to each batch
//! of input graphs and assigning it weight 0 for training the GNN".
//! [`pad`] appends exactly one padding component that brings every
//! node/edge set up to its [`PadSpec`] cap; padding edges connect
//! padding nodes only, so the component invariant (no edges across
//! components) is preserved and segment ops stay correct. Per-item
//! validity masks are returned alongside the graph and flow into the
//! AOT train step, which multiplies the loss and metrics by them.
//!
//! [`fit_or_skip`] mirrors the Runner's `FitOrSkipPadding` (A.5): a
//! batch that exceeds the caps is skipped (with a counter) instead of
//! aborting training.

use std::collections::BTreeMap;

use super::tensor::{Feature, GraphTensor};
use crate::{Error, Result};

/// Static size caps for every node and edge set.
#[derive(Debug, Clone, PartialEq)]
pub struct PadSpec {
    /// Cap on total nodes per node set (including padding).
    pub node_caps: BTreeMap<String, usize>,
    /// Cap on total edges per edge set (including padding).
    pub edge_caps: BTreeMap<String, usize>,
    /// Cap on total components (including the one padding component).
    pub component_cap: usize,
}

impl PadSpec {
    /// A spec that fits `batch_size` graphs like `sample`, with `slack`
    /// multiplicative headroom (≥ 1.0). Useful for deriving caps from a
    /// dataset prefix, as the Runner's size estimator does.
    pub fn fit(sample: &[&GraphTensor], batch_size: usize, slack: f64) -> PadSpec {
        let mut node_caps = BTreeMap::new();
        let mut edge_caps = BTreeMap::new();
        for g in sample {
            for (name, ns) in &g.node_sets {
                let e = node_caps.entry(name.clone()).or_insert(0usize);
                *e = (*e).max(ns.total());
            }
            for (name, es) in &g.edge_sets {
                let e = edge_caps.entry(name.clone()).or_insert(0usize);
                *e = (*e).max(es.total());
            }
        }
        // Scale per-graph maxima to a batch cap, +1 node of headroom for
        // the padding component's sink nodes.
        for v in node_caps.values_mut() {
            *v = (*v as f64 * batch_size as f64 * slack).ceil() as usize + 1;
        }
        for v in edge_caps.values_mut() {
            *v = (*v as f64 * batch_size as f64 * slack).ceil() as usize;
        }
        PadSpec { node_caps, edge_caps, component_cap: batch_size + 1 }
    }

    pub fn node_cap(&self, set: &str) -> Result<usize> {
        self.node_caps
            .get(set)
            .copied()
            .ok_or_else(|| Error::Graph(format!("PadSpec missing node cap for {set:?}")))
    }

    pub fn edge_cap(&self, set: &str) -> Result<usize> {
        self.edge_caps
            .get(set)
            .copied()
            .ok_or_else(|| Error::Graph(format!("PadSpec missing edge cap for {set:?}")))
    }
}

/// A padded batch: the static-shape graph plus validity masks.
#[derive(Debug, Clone)]
pub struct Padded {
    pub graph: GraphTensor,
    /// 1.0 for real items, 0.0 for padding, per node set (len = cap).
    pub node_mask: BTreeMap<String, Vec<f32>>,
    /// Same for edges.
    pub edge_mask: BTreeMap<String, Vec<f32>>,
    /// Components that carry real data (the last one is padding).
    pub num_real_components: usize,
}

/// Does `graph` fit under `spec` with room for the padding component?
pub fn fits(graph: &GraphTensor, spec: &PadSpec) -> bool {
    if graph.num_components + 1 > spec.component_cap {
        return false;
    }
    for (name, ns) in &graph.node_sets {
        match spec.node_caps.get(name) {
            // Strict: padding needs ≥1 node in every set so padding
            // edges have an endpoint.
            Some(&cap) if ns.total() < cap => {}
            _ => return false,
        }
    }
    for (name, es) in &graph.edge_sets {
        match spec.edge_caps.get(name) {
            Some(&cap) if es.total() <= cap => {}
            _ => return false,
        }
    }
    true
}

/// Pad `graph` (typically a merged batch) to the exact sizes of `spec`.
pub fn pad(graph: &GraphTensor, spec: &PadSpec) -> Result<Padded> {
    if !fits(graph, spec) {
        return Err(Error::Graph(format!(
            "graph does not fit PadSpec (components {} + 1 > {}, or a set exceeds its cap)",
            graph.num_components, spec.component_cap
        )));
    }
    let mut g = graph.clone();
    let mut node_mask = BTreeMap::new();
    let mut edge_mask = BTreeMap::new();

    // One padding component on every piece.
    g.num_components += 1;

    // Node sets: append cap - total zero-feature nodes.
    let mut pad_node_start: BTreeMap<String, u32> = BTreeMap::new();
    for (name, ns) in g.node_sets.iter_mut() {
        let total = ns.total();
        let cap = spec.node_cap(name)?;
        let extra = cap - total;
        pad_node_start.insert(name.clone(), total as u32);
        ns.sizes.push(extra);
        for (fname, f) in ns.features.iter_mut() {
            pad_feature(f, extra).map_err(|e| {
                Error::Graph(format!("padding node feature {name}/{fname}: {e}"))
            })?;
        }
        let mut mask = vec![1.0f32; total];
        mask.resize(cap, 0.0);
        node_mask.insert(name.clone(), mask);
    }

    // Edge sets: append cap - total edges between padding nodes.
    for (name, es) in g.edge_sets.iter_mut() {
        let total = es.total();
        let cap = spec.edge_cap(name)?;
        let extra = cap - total;
        es.sizes.push(extra);
        let src_sink = pad_node_start[&es.adjacency.source_set];
        let tgt_sink = pad_node_start[&es.adjacency.target_set];
        es.adjacency.source.extend(std::iter::repeat(src_sink).take(extra));
        es.adjacency.target.extend(std::iter::repeat(tgt_sink).take(extra));
        // The adjacency changed: drop any CSR view inherited from the
        // unpadded graph's cache (it is memoized per EdgeSet).
        es.invalidate_csr();
        for (fname, f) in es.features.iter_mut() {
            pad_feature(f, extra).map_err(|e| {
                Error::Graph(format!("padding edge feature {name}/{fname}: {e}"))
            })?;
        }
        let mut mask = vec![1.0f32; total];
        mask.resize(cap, 0.0);
        edge_mask.insert(name.clone(), mask);
    }

    // Context features get one zero row for the padding component.
    for f in g.context.features.values_mut() {
        pad_feature(f, 1)?;
    }

    g.validate()?;
    Ok(Padded { graph: g, node_mask, edge_mask, num_real_components: graph.num_components })
}

/// `FitOrSkipPadding`: pad, or return `None` when the batch exceeds the
/// caps. Callers count skips (a training-quality metric in the Runner).
pub fn fit_or_skip(graph: &GraphTensor, spec: &PadSpec) -> Option<Padded> {
    if fits(graph, spec) {
        // fits() implies pad() succeeds; a failure (impossible by
        // construction) degrades to a counted skip, never a panic.
        pad(graph, spec).ok()
    } else {
        None
    }
}

/// Remove padding given the original component count — used in tests to
/// verify padding is lossless, and by readout paths that want real rows.
pub fn unpad(padded: &Padded) -> Result<GraphTensor> {
    let comps = super::batch::split(&padded.graph)?;
    let real = &comps[..padded.num_real_components];
    super::batch::merge(real)
}

fn pad_feature(f: &mut Feature, extra: usize) -> Result<()> {
    match f {
        Feature::F32 { dims, data } => {
            let per: usize = dims.iter().product::<usize>().max(1);
            data.extend(std::iter::repeat(0.0).take(extra * per));
        }
        Feature::I64 { dims, data } => {
            let per: usize = dims.iter().product::<usize>().max(1);
            data.extend(std::iter::repeat(0).take(extra * per));
        }
        Feature::Str { data } => {
            data.extend(std::iter::repeat(String::new()).take(extra));
        }
        Feature::RaggedF32 { row_splits, .. } => {
            let last = row_splits.last().copied().unwrap_or(0);
            row_splits.extend(std::iter::repeat(last).take(extra));
        }
        Feature::RaggedI64 { row_splits, .. } => {
            let last = row_splits.last().copied().unwrap_or(0);
            row_splits.extend(std::iter::repeat(last).take(extra));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::batch::{merge, random_graph, random_graph_with_dim};
    use crate::synth::recsys::recsys_example_graph;
    use crate::util::proptest::check;

    fn recsys_spec() -> PadSpec {
        PadSpec {
            node_caps: [("items".to_string(), 10), ("users".to_string(), 8)].into(),
            edge_caps: [("purchased".to_string(), 12), ("is-friend".to_string(), 6)].into(),
            component_cap: 3,
        }
    }

    #[test]
    fn pad_reaches_exact_caps() {
        let g = recsys_example_graph().unwrap();
        let p = pad(&g, &recsys_spec()).unwrap();
        assert_eq!(p.graph.num_nodes("items").unwrap(), 10);
        assert_eq!(p.graph.num_nodes("users").unwrap(), 8);
        assert_eq!(p.graph.num_edges("purchased").unwrap(), 12);
        assert_eq!(p.graph.num_edges("is-friend").unwrap(), 6);
        assert_eq!(p.graph.num_components, 2);
        assert_eq!(p.num_real_components, 1);
    }

    #[test]
    fn masks_mark_real_items() {
        let g = recsys_example_graph().unwrap();
        let p = pad(&g, &recsys_spec()).unwrap();
        let m = &p.node_mask["items"];
        assert_eq!(m.len(), 10);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 6);
        assert!(m[..6].iter().all(|&x| x == 1.0));
        assert!(m[6..].iter().all(|&x| x == 0.0));
        let em = &p.edge_mask["purchased"];
        assert_eq!(em.iter().sum::<f32>(), 7.0);
    }

    #[test]
    fn padding_edges_stay_in_padding_component() {
        let g = recsys_example_graph().unwrap();
        let p = pad(&g, &recsys_spec()).unwrap();
        // validate() enforces the component invariant; also check sink.
        p.graph.validate().unwrap();
        let es = p.graph.edge_set("purchased").unwrap();
        for e in 7..12 {
            assert_eq!(es.adjacency.source[e], 6, "padding edge source is first padding item");
            assert_eq!(es.adjacency.target[e], 4, "padding edge target is first padding user");
        }
    }

    #[test]
    fn unpad_is_lossless() {
        let g = recsys_example_graph().unwrap();
        let p = pad(&g, &recsys_spec()).unwrap();
        let back = unpad(&p).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn oversized_graph_skipped() {
        let g = recsys_example_graph().unwrap();
        let tight = PadSpec {
            node_caps: [("items".to_string(), 6), ("users".to_string(), 8)].into(),
            edge_caps: [("purchased".to_string(), 12), ("is-friend".to_string(), 6)].into(),
            component_cap: 3,
        };
        // items cap == total: no room for the padding sink node -> skip.
        assert!(fit_or_skip(&g, &tight).is_none());
        assert!(pad(&g, &tight).is_err());
    }

    #[test]
    fn missing_cap_fails() {
        let g = recsys_example_graph().unwrap();
        let mut spec = recsys_spec();
        spec.node_caps.remove("users");
        assert!(!fits(&g, &spec));
    }

    #[test]
    fn context_padded_per_component() {
        let g = recsys_example_graph().unwrap();
        let p = pad(&g, &recsys_spec()).unwrap();
        let scores = p.graph.context.feature("scores").unwrap();
        let (_, data) = scores.as_f32().unwrap();
        assert_eq!(data.len(), 8); // 2 components × 4
        assert!(data[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_pad_unpad_roundtrip() {
        check("unpad(pad(g)) == g", 40, |rng| {
            let k = 1 + rng.uniform(3);
            let dim = 1 + rng.uniform(4);
            let batch: Vec<_> = (0..k).map(|_| random_graph_with_dim(rng, dim)).collect();
            let g = merge(&batch).unwrap();
            let spec = PadSpec::fit(&batch.iter().collect::<Vec<_>>(), k, 1.5);
            let p = pad(&g, &spec).unwrap();
            assert_eq!(unpad(&p).unwrap(), g);
        });
    }

    #[test]
    fn prop_fit_spec_always_fits() {
        check("PadSpec::fit admits its own sample", 40, |rng| {
            let k = 1 + rng.uniform(4);
            let dim = 1 + rng.uniform(4);
            let batch: Vec<_> = (0..k).map(|_| random_graph_with_dim(rng, dim)).collect();
            let spec = PadSpec::fit(&batch.iter().collect::<Vec<_>>(), k, 1.0);
            let g = merge(&batch).unwrap();
            assert!(fits(&g, &spec), "sample-derived spec must admit the sample batch");
        });
    }

    #[test]
    fn prop_mask_sums_equal_real_counts() {
        check("mask sums = real item counts", 40, |rng| {
            let g = random_graph(rng);
            let spec = PadSpec::fit(&[&g], 2, 1.25);
            let p = pad(&g, &spec).unwrap();
            for (name, mask) in &p.node_mask {
                assert_eq!(mask.iter().sum::<f32>() as usize, g.num_nodes(name).unwrap());
            }
            for (name, mask) in &p.edge_mask {
                assert_eq!(mask.iter().sum::<f32>() as usize, g.num_edges(name).unwrap());
            }
        });
    }
}
