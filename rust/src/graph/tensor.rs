//! Core GraphTensor containers and structural validation.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::csr::{self, Csr, Incidence};
use crate::schema::{DType, FeatureSpec, GraphSchema};
use crate::{Error, Result};

/// A feature tensor over the items of one node/edge set (or over the
/// components of the graph, for context features).
///
/// Dense variants store row-major data of shape `[n, dims…]`; ragged
/// variants store a flat value buffer plus `row_splits` (length `n+1`),
/// mirroring `tf.RaggedTensor`.
#[derive(Debug, Clone, PartialEq)]
pub enum Feature {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I64 { dims: Vec<usize>, data: Vec<i64> },
    Str { data: Vec<String> },
    RaggedF32 { row_splits: Vec<usize>, data: Vec<f32> },
    RaggedI64 { row_splits: Vec<usize>, data: Vec<i64> },
}

impl Feature {
    /// Number of items (leading dimension `n`).
    pub fn len(&self) -> usize {
        match self {
            Feature::F32 { dims, data } => div_len(data.len(), dims),
            Feature::I64 { dims, data } => div_len(data.len(), dims),
            Feature::Str { data } => data.len(),
            Feature::RaggedF32 { row_splits, .. } | Feature::RaggedI64 { row_splits, .. } => {
                row_splits.len().saturating_sub(1)
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Feature::F32 { .. } | Feature::RaggedF32 { .. } => DType::F32,
            Feature::I64 { .. } | Feature::RaggedI64 { .. } => DType::I64,
            Feature::Str { .. } => DType::Str,
        }
    }

    pub fn is_ragged(&self) -> bool {
        matches!(self, Feature::RaggedF32 { .. } | Feature::RaggedI64 { .. })
    }

    /// Dense f32 accessors (most ops work on these).
    pub fn as_f32(&self) -> Result<(&[usize], &[f32])> {
        match self {
            Feature::F32 { dims, data } => Ok((dims, data)),
            other => Err(Error::Feature(format!(
                "expected dense f32 feature, got {:?}",
                other.dtype()
            ))),
        }
    }

    pub fn as_i64(&self) -> Result<(&[usize], &[i64])> {
        match self {
            Feature::I64 { dims, data } => Ok((dims, data)),
            other => Err(Error::Feature(format!(
                "expected dense i64 feature, got {:?}",
                other.dtype()
            ))),
        }
    }

    pub fn as_str(&self) -> Result<&[String]> {
        match self {
            Feature::Str { data } => Ok(data),
            other => {
                Err(Error::Feature(format!("expected string feature, got {:?}", other.dtype())))
            }
        }
    }

    /// Row `i` of a ragged f32 feature.
    pub fn ragged_row_f32(&self, i: usize) -> Result<&[f32]> {
        match self {
            Feature::RaggedF32 { row_splits, data } => {
                Ok(&data[row_splits[i]..row_splits[i + 1]])
            }
            other => {
                Err(Error::Feature(format!("expected ragged f32, got {:?}", other.dtype())))
            }
        }
    }

    /// Scalar-f32 vector helper.
    pub fn f32_vec(data: Vec<f32>) -> Feature {
        Feature::F32 { dims: vec![], data }
    }

    /// Dense f32 matrix `[n, d]` helper.
    pub fn f32_mat(d: usize, data: Vec<f32>) -> Feature {
        Feature::F32 { dims: vec![d], data }
    }

    pub fn i64_vec(data: Vec<i64>) -> Feature {
        Feature::I64 { dims: vec![], data }
    }

    pub fn str_vec(data: Vec<&str>) -> Feature {
        Feature::Str { data: data.into_iter().map(|s| s.to_string()).collect() }
    }

    /// Build a rank-1 ragged f32 feature from rows.
    pub fn ragged_f32(rows: Vec<Vec<f32>>) -> Feature {
        let mut row_splits = Vec::with_capacity(rows.len() + 1);
        let mut data = Vec::new();
        row_splits.push(0);
        for row in rows {
            data.extend_from_slice(&row);
            row_splits.push(data.len());
        }
        Feature::RaggedF32 { row_splits, data }
    }

    /// Structural validation: item count matches `n`, dense buffer size
    /// divides evenly, ragged splits are monotone and cover the buffer.
    pub fn validate(&self, n: usize, name: &str) -> Result<()> {
        match self {
            Feature::F32 { dims, data } => validate_dense(data.len(), dims, n, name),
            Feature::I64 { dims, data } => validate_dense(data.len(), dims, n, name),
            Feature::Str { data } => {
                if data.len() != n {
                    return Err(Error::Feature(format!(
                        "feature {name:?}: {} strings for {n} items",
                        data.len()
                    )));
                }
                Ok(())
            }
            Feature::RaggedF32 { row_splits, data } => {
                validate_ragged(row_splits, data.len(), n, name)
            }
            Feature::RaggedI64 { row_splits, data } => {
                validate_ragged(row_splits, data.len(), n, name)
            }
        }
    }

    /// Does this feature value conform to a schema feature spec?
    pub fn matches_spec(&self, spec: &FeatureSpec) -> bool {
        if self.dtype() != spec.dtype {
            return false;
        }
        match self {
            Feature::F32 { dims, .. } | Feature::I64 { dims, .. } => {
                !spec.is_ragged()
                    && dims.len() == spec.shape.len()
                    && dims.iter().zip(&spec.shape).all(|(d, s)| Some(*d) == *s)
            }
            Feature::Str { .. } => spec.shape.is_empty(),
            Feature::RaggedF32 { .. } | Feature::RaggedI64 { .. } => {
                spec.shape.len() == 1 && spec.shape[0].is_none()
            }
        }
    }
}

fn div_len(total: usize, dims: &[usize]) -> usize {
    let per = dims.iter().product::<usize>().max(1);
    total / per
}

fn validate_dense(total: usize, dims: &[usize], n: usize, name: &str) -> Result<()> {
    let per = dims.iter().product::<usize>();
    if dims.iter().any(|&d| d == 0) {
        if n != 0 && total != 0 {
            return Err(Error::Feature(format!("feature {name:?}: zero dim with data")));
        }
        return Ok(());
    }
    if total != per * n {
        return Err(Error::Feature(format!(
            "feature {name:?}: buffer len {total} != {n} items × {per} elems"
        )));
    }
    Ok(())
}

fn validate_ragged(row_splits: &[usize], total: usize, n: usize, name: &str) -> Result<()> {
    if row_splits.len() != n + 1 {
        return Err(Error::Feature(format!(
            "feature {name:?}: {} row_splits for {n} items",
            row_splits.len()
        )));
    }
    if row_splits.first() != Some(&0) || row_splits.last() != Some(&total) {
        return Err(Error::Feature(format!("feature {name:?}: row_splits must span [0, {total}]")));
    }
    if row_splits.windows(2).any(|w| w[0] > w[1]) {
        return Err(Error::Feature(format!("feature {name:?}: row_splits not monotone")));
    }
    Ok(())
}

/// Edge endpoints: parallel index arrays into the named node sets.
#[derive(Debug, Clone, PartialEq)]
pub struct Adjacency {
    pub source_set: String,
    pub target_set: String,
    pub source: Vec<u32>,
    pub target: Vec<u32>,
}

impl Adjacency {
    pub fn len(&self) -> usize {
        self.source.len()
    }

    pub fn is_empty(&self) -> bool {
        self.source.is_empty()
    }
}

/// A node set instance: per-component sizes plus features.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NodeSet {
    /// Number of nodes contributed by each graph component; the total
    /// node count is `sizes.iter().sum()`.
    pub sizes: Vec<usize>,
    pub features: BTreeMap<String, Feature>,
}

impl NodeSet {
    pub fn new(sizes: Vec<usize>) -> NodeSet {
        NodeSet { sizes, features: BTreeMap::new() }
    }

    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }

    pub fn with_feature(mut self, name: &str, f: Feature) -> NodeSet {
        self.features.insert(name.to_string(), f);
        self
    }

    pub fn feature(&self, name: &str) -> Result<&Feature> {
        self.features
            .get(name)
            .ok_or_else(|| Error::Feature(format!("node feature {name:?} not found")))
    }
}

/// An edge set instance: per-component sizes, adjacency, features,
/// plus a lazily-built CSR view of the adjacency (derived state; see
/// [`csr::CsrCache`] — ignored by equality and serialization).
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeSet {
    pub sizes: Vec<usize>,
    pub adjacency: Adjacency,
    pub features: BTreeMap<String, Feature>,
    pub(crate) csr: csr::CsrCache,
}

impl EdgeSet {
    pub fn new(sizes: Vec<usize>, adjacency: Adjacency) -> EdgeSet {
        EdgeSet { sizes, adjacency, features: BTreeMap::new(), csr: csr::CsrCache::new() }
    }

    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }

    pub fn with_feature(mut self, name: &str, f: Feature) -> EdgeSet {
        self.features.insert(name.to_string(), f);
        self
    }

    pub fn feature(&self, name: &str) -> Result<&Feature> {
        self.features
            .get(name)
            .ok_or_else(|| Error::Feature(format!("edge feature {name:?} not found")))
    }

    /// Drop any memoized CSR views. Call after mutating `adjacency` —
    /// or resizing an endpoint node set — in place (the fields are
    /// public, so the cache cannot observe the change itself);
    /// constructors start with an empty cache. `GraphTensor::csr` has a
    /// size-based staleness tripwire, but same-size index rewrites are
    /// only caught by calling this.
    pub fn invalidate_csr(&mut self) {
        self.csr = csr::CsrCache::new();
    }
}

/// Graph-level (per-component) features.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Context {
    pub features: BTreeMap<String, Feature>,
}

impl Context {
    pub fn with_feature(mut self, name: &str, f: Feature) -> Context {
        self.features.insert(name.to_string(), f);
        self
    }

    pub fn feature(&self, name: &str) -> Result<&Feature> {
        self.features
            .get(name)
            .ok_or_else(|| Error::Feature(format!("context feature {name:?} not found")))
    }
}

/// A scalar GraphTensor with `num_components()` merged input graphs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GraphTensor {
    pub context: Context,
    pub node_sets: BTreeMap<String, NodeSet>,
    pub edge_sets: BTreeMap<String, EdgeSet>,
    /// Number of graph components (1 for a freshly parsed input).
    pub num_components: usize,
}

impl GraphTensor {
    /// A single-component graph from pieces (the `from_pieces` of A.2.2).
    pub fn from_pieces(
        context: Context,
        node_sets: BTreeMap<String, NodeSet>,
        edge_sets: BTreeMap<String, EdgeSet>,
    ) -> Result<GraphTensor> {
        let num_components = node_sets
            .values()
            .map(|ns| ns.sizes.len())
            .chain(edge_sets.values().map(|es| es.sizes.len()))
            .max()
            .unwrap_or(1)
            .max(1);
        let gt = GraphTensor { context, node_sets, edge_sets, num_components };
        gt.validate()?;
        Ok(gt)
    }

    pub fn node_set(&self, name: &str) -> Result<&NodeSet> {
        self.node_sets
            .get(name)
            .ok_or_else(|| Error::Graph(format!("unknown node set {name:?}")))
    }

    pub fn edge_set(&self, name: &str) -> Result<&EdgeSet> {
        self.edge_sets
            .get(name)
            .ok_or_else(|| Error::Graph(format!("unknown edge set {name:?}")))
    }

    /// Total nodes across components in a set.
    pub fn num_nodes(&self, set: &str) -> Result<usize> {
        Ok(self.node_set(set)?.total())
    }

    pub fn num_edges(&self, set: &str) -> Result<usize> {
        Ok(self.edge_set(set)?.total())
    }

    /// The memoized CSR view of an edge set's adjacency, keyed by the
    /// `inc` endpoint (the receiver of a pool). Built on first use;
    /// subsequent calls — later model layers, repeated ops on the same
    /// graph, clones of this graph — share the same `Arc`.
    ///
    /// Building validates both endpoint index arrays against their
    /// node-set sizes, so corrupt adjacency surfaces as
    /// [`Error::Graph`] here rather than a slice panic in a kernel.
    pub fn csr(&self, edge_set: &str, inc: Incidence) -> Result<Arc<Csr>> {
        let es = self.edge_set(edge_set)?;
        let (keyed, opposite, keyed_set, opposite_set) = match inc {
            Incidence::BySource => (
                &es.adjacency.source,
                &es.adjacency.target,
                &es.adjacency.source_set,
                &es.adjacency.target_set,
            ),
            Incidence::ByTarget => (
                &es.adjacency.target,
                &es.adjacency.source,
                &es.adjacency.target_set,
                &es.adjacency.source_set,
            ),
        };
        let n_keyed = self.num_nodes(keyed_set)?;
        let n_opposite = self.num_nodes(opposite_set)?;
        let csr = es
            .csr
            .get_or_build(inc, || Csr::build(edge_set, keyed, opposite, n_keyed, n_opposite))?;
        // Cheap staleness tripwire: the fields are public, so adjacency
        // or node sets may have been mutated after the view was built
        // without `invalidate_csr`. Catch the size-changing cases
        // (anything else is on the mutator) instead of silently
        // returning wrong-shaped results.
        if csr.num_nodes() != n_keyed || csr.num_edges() != keyed.len() {
            return Err(Error::Graph(format!(
                "edge set {edge_set:?}: stale CSR cache ({} nodes / {} edges cached, \
                 {n_keyed} / {} now) — call EdgeSet::invalidate_csr after mutating \
                 adjacency or node sets",
                csr.num_nodes(),
                csr.num_edges(),
                keyed.len()
            )));
        }
        Ok(csr)
    }

    /// Structural invariants:
    /// * every piece has `num_components` sizes,
    /// * feature item counts match set totals,
    /// * adjacency indices are in range and stay within their component,
    /// * context features have `num_components` items.
    pub fn validate(&self) -> Result<()> {
        for (name, ns) in &self.node_sets {
            if ns.sizes.len() != self.num_components {
                return Err(Error::Graph(format!(
                    "node set {name:?} has {} component sizes, graph has {}",
                    ns.sizes.len(),
                    self.num_components
                )));
            }
            for (fname, f) in &ns.features {
                f.validate(ns.total(), &format!("{name}/{fname}"))?;
            }
        }
        for (name, es) in &self.edge_sets {
            if es.sizes.len() != self.num_components {
                return Err(Error::Graph(format!(
                    "edge set {name:?} has {} component sizes, graph has {}",
                    es.sizes.len(),
                    self.num_components
                )));
            }
            if es.adjacency.source.len() != es.total() || es.adjacency.target.len() != es.total()
            {
                return Err(Error::Graph(format!(
                    "edge set {name:?}: adjacency lengths {}/{} != size {}",
                    es.adjacency.source.len(),
                    es.adjacency.target.len(),
                    es.total()
                )));
            }
            for (fname, f) in &es.features {
                f.validate(es.total(), &format!("{name}/{fname}"))?;
            }
            let src_set = self.node_set(&es.adjacency.source_set).map_err(|_| {
                Error::Graph(format!(
                    "edge set {name:?} references unknown source node set {:?}",
                    es.adjacency.source_set
                ))
            })?;
            let tgt_set = self.node_set(&es.adjacency.target_set).map_err(|_| {
                Error::Graph(format!(
                    "edge set {name:?} references unknown target node set {:?}",
                    es.adjacency.target_set
                ))
            })?;
            // Component-respecting index check: edges of component c may
            // only reference nodes of component c (§3.2: "standard GNN
            // operations respect the boundaries between merged graphs
            // because there are no edges connecting them").
            check_indices_in_components(name, "source", &es.sizes, &es.adjacency.source, src_set)?;
            check_indices_in_components(name, "target", &es.sizes, &es.adjacency.target, tgt_set)?;
        }
        for (fname, f) in &self.context.features {
            f.validate(self.num_components, &format!("context/{fname}"))?;
        }
        Ok(())
    }

    /// Validate against a schema: all declared pieces exist with
    /// conforming feature dtypes/shapes (extra features are allowed,
    /// mirroring TF-GNN's feature-engineering flow).
    pub fn check_compatible_with_schema(&self, schema: &GraphSchema) -> Result<()> {
        for (name, spec) in &schema.node_sets {
            let ns = self.node_set(name)?;
            for (fname, fspec) in &spec.features {
                let f = ns.feature(fname)?;
                if !f.matches_spec(fspec) {
                    return Err(Error::Feature(format!(
                        "node feature {name}/{fname} does not match schema spec"
                    )));
                }
            }
        }
        for (name, spec) in &schema.edge_sets {
            let es = self.edge_set(name)?;
            if es.adjacency.source_set != spec.source || es.adjacency.target_set != spec.target {
                return Err(Error::Schema(format!(
                    "edge set {name:?} endpoints ({} -> {}) differ from schema ({} -> {})",
                    es.adjacency.source_set, es.adjacency.target_set, spec.source, spec.target
                )));
            }
            for (fname, fspec) in &spec.features {
                let f = es.feature(fname)?;
                if !f.matches_spec(fspec) {
                    return Err(Error::Feature(format!(
                        "edge feature {name}/{fname} does not match schema spec"
                    )));
                }
            }
        }
        for (fname, fspec) in &schema.context {
            let f = self.context.feature(fname)?;
            if !f.matches_spec(fspec) {
                return Err(Error::Feature(format!(
                    "context feature {fname} does not match schema spec"
                )));
            }
        }
        Ok(())
    }

    /// Replace (some) features of a node set, returning a new graph —
    /// TF-GNN's `replace_features` (§3.2, A.3).
    pub fn replace_node_features(
        &self,
        set: &str,
        features: BTreeMap<String, Feature>,
    ) -> Result<GraphTensor> {
        let mut g = self.clone();
        let ns = g
            .node_sets
            .get_mut(set)
            .ok_or_else(|| Error::Graph(format!("unknown node set {set:?}")))?;
        ns.features = features;
        g.validate()?;
        Ok(g)
    }

    /// Approximate in-memory footprint in bytes (used by pipeline
    /// backpressure accounting and bench reports).
    pub fn approx_bytes(&self) -> usize {
        let feat_bytes = |f: &Feature| -> usize {
            match f {
                Feature::F32 { data, .. } => data.len() * 4,
                Feature::I64 { data, .. } => data.len() * 8,
                Feature::Str { data } => data.iter().map(|s| s.len() + 24).sum(),
                Feature::RaggedF32 { row_splits, data } => data.len() * 4 + row_splits.len() * 8,
                Feature::RaggedI64 { row_splits, data } => (data.len() + row_splits.len()) * 8,
            }
        };
        let mut total = 0;
        for ns in self.node_sets.values() {
            total += ns.sizes.len() * 8;
            total += ns.features.values().map(feat_bytes).sum::<usize>();
        }
        for es in self.edge_sets.values() {
            total += es.sizes.len() * 8 + es.adjacency.len() * 8;
            total += es.features.values().map(feat_bytes).sum::<usize>();
        }
        total += self.context.features.values().map(feat_bytes).sum::<usize>();
        total
    }
}

fn check_indices_in_components(
    edge_set: &str,
    role: &str,
    edge_sizes: &[usize],
    indices: &[u32],
    node_set: &NodeSet,
) -> Result<()> {
    let mut edge_off = 0usize;
    let mut node_off = 0usize;
    for (c, (&esize, &nsize)) in edge_sizes.iter().zip(&node_set.sizes).enumerate() {
        for &idx in &indices[edge_off..edge_off + esize] {
            let idx = idx as usize;
            if idx < node_off || idx >= node_off + nsize {
                return Err(Error::Graph(format!(
                    "edge set {edge_set:?} {role} index {idx} escapes component {c} \
                     (node range {node_off}..{})",
                    node_off + nsize
                )));
            }
        }
        edge_off += esize;
        node_off += nsize;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::recsys_example_schema;

    use crate::synth::recsys::recsys_example_graph;

    #[test]
    fn recsys_graph_validates_and_matches_schema() {
        let g = recsys_example_graph().unwrap();
        assert_eq!(g.num_components, 1);
        assert_eq!(g.num_nodes("items").unwrap(), 6);
        assert_eq!(g.num_nodes("users").unwrap(), 4);
        assert_eq!(g.num_edges("purchased").unwrap(), 7);
        g.check_compatible_with_schema(&recsys_example_schema()).unwrap();
    }

    #[test]
    fn a1_worked_example_indices() {
        // "the fifth values of purchased/#source and #target are [4, 2]
        //  which link together 'flight' and 'Yumiko'" (A.1).
        let g = recsys_example_graph().unwrap();
        let es = g.edge_set("purchased").unwrap();
        assert_eq!(es.adjacency.source[4], 4);
        assert_eq!(es.adjacency.target[4], 2);
        let items = g.node_set("items").unwrap();
        assert_eq!(items.feature("category").unwrap().as_str().unwrap()[4], "flight");
        let users = g.node_set("users").unwrap();
        assert_eq!(users.feature("name").unwrap().as_str().unwrap()[2], "Yumiko");
    }

    #[test]
    fn ragged_feature_rows() {
        let g = recsys_example_graph().unwrap();
        let price = g.node_set("items").unwrap().feature("price").unwrap();
        assert_eq!(price.len(), 6);
        assert_eq!(price.ragged_row_f32(0).unwrap(), &[22.34, 23.42, 12.99]);
        assert_eq!(price.ragged_row_f32(2).unwrap(), &[89.99]);
        assert_eq!(price.ragged_row_f32(5).unwrap().len(), 3);
    }

    #[test]
    fn out_of_range_edge_index_rejected() {
        let mut g = recsys_example_graph().unwrap();
        g.edge_sets.get_mut("purchased").unwrap().adjacency.target[0] = 99;
        assert!(g.validate().is_err());
    }

    #[test]
    fn cross_component_edge_rejected() {
        // Two components: nodes [2, 2]; an edge in component 0 pointing
        // at a node of component 1 must be rejected.
        let ns = NodeSet::new(vec![2, 2]);
        let es = EdgeSet::new(
            vec![1, 0],
            Adjacency {
                source_set: "n".into(),
                target_set: "n".into(),
                source: vec![0],
                target: vec![2], // component 1's first node
            },
        );
        let g = GraphTensor {
            context: Context::default(),
            node_sets: [("n".to_string(), ns)].into(),
            edge_sets: [("e".to_string(), es)].into(),
            num_components: 2,
        };
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("escapes component"), "{err}");
    }

    #[test]
    fn feature_length_mismatch_rejected() {
        let ns = NodeSet::new(vec![3]).with_feature("x", Feature::f32_vec(vec![1.0, 2.0]));
        let g = GraphTensor {
            context: Context::default(),
            node_sets: [("n".to_string(), ns)].into(),
            edge_sets: BTreeMap::new(),
            num_components: 1,
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn ragged_validation() {
        // Bad row_splits: not starting at 0.
        let f = Feature::RaggedF32 { row_splits: vec![1, 2], data: vec![1.0, 2.0] };
        assert!(f.validate(1, "x").is_err());
        // Not covering the buffer.
        let f = Feature::RaggedF32 { row_splits: vec![0, 1], data: vec![1.0, 2.0] };
        assert!(f.validate(1, "x").is_err());
        // Non-monotone.
        let f = Feature::RaggedF32 { row_splits: vec![0, 2, 1], data: vec![1.0, 2.0] };
        assert!(f.validate(2, "x").is_err());
        // Good.
        let f = Feature::ragged_f32(vec![vec![1.0], vec![], vec![2.0, 3.0]]);
        f.validate(3, "x").unwrap();
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn replace_features_keeps_validation() {
        let g = recsys_example_graph().unwrap();
        // A.3: materialize "latest_price" = first price entry per item.
        let price = g.node_set("items").unwrap().feature("price").unwrap().clone();
        let latest: Vec<f32> = (0..6).map(|i| price.ragged_row_f32(i).unwrap()[0]).collect();
        let mut feats = g.node_set("items").unwrap().features.clone();
        feats.insert("latest_price".into(), Feature::f32_vec(latest));
        let g2 = g.replace_node_features("items", feats).unwrap();
        let lp = g2.node_set("items").unwrap().feature("latest_price").unwrap();
        let (_, vals) = lp.as_f32().unwrap();
        assert_eq!(vals[0], 22.34);
        assert_eq!(vals[4], 350.00);
    }

    #[test]
    fn matches_spec_checks() {
        use crate::schema::FeatureSpec;
        assert!(Feature::f32_mat(4, vec![0.0; 8]).matches_spec(&FeatureSpec::f32(&[4])));
        assert!(!Feature::f32_mat(4, vec![0.0; 8]).matches_spec(&FeatureSpec::f32(&[5])));
        assert!(!Feature::f32_mat(4, vec![0.0; 8]).matches_spec(&FeatureSpec::i64(&[4])));
        assert!(Feature::ragged_f32(vec![vec![1.0]]).matches_spec(&FeatureSpec::ragged_f32()));
        assert!(!Feature::ragged_f32(vec![vec![1.0]]).matches_spec(&FeatureSpec::f32(&[1])));
        assert!(Feature::str_vec(vec!["a"]).matches_spec(&FeatureSpec::string()));
    }

    #[test]
    fn approx_bytes_positive() {
        let g = recsys_example_graph().unwrap();
        assert!(g.approx_bytes() > 100);
    }
}
