//! Batching and merging (paper §3.2).
//!
//! TF-GNN batches input graphs and then *merges* the batch into a single
//! scalar GraphTensor: per node/edge set, features are concatenated
//! across the batch and edge indices are shifted so each input graph
//! becomes one **component** of the result, with a flat index space
//! `0..n_total` per set. Context features become per-component rows.
//!
//! [`merge`] implements that; [`split`] is the inverse (used for
//! readout, debugging and the merge↔split property tests).

use std::collections::BTreeMap;

use super::tensor::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use crate::{Error, Result};

/// Merge a batch of GraphTensors into one scalar GraphTensor whose
/// components are the inputs, in order.
///
/// All inputs must have the same node/edge-set names, feature names,
/// dtypes and feature shapes (as they do when parsed from one schema).
pub fn merge(batch: &[GraphTensor]) -> Result<GraphTensor> {
    if batch.is_empty() {
        return Err(Error::Graph("merge of empty batch".into()));
    }
    let total_components: usize = batch.iter().map(|g| g.num_components).sum();

    // Node sets.
    let mut node_sets: BTreeMap<String, NodeSet> = BTreeMap::new();
    for name in batch[0].node_sets.keys() {
        let mut sizes = Vec::with_capacity(total_components);
        let mut features: BTreeMap<String, Vec<&Feature>> = BTreeMap::new();
        for g in batch {
            let ns = g.node_set(name)?;
            sizes.extend_from_slice(&ns.sizes);
            for (fname, f) in &ns.features {
                features.entry(fname.clone()).or_default().push(f);
            }
        }
        let mut merged = NodeSet::new(sizes);
        for (fname, parts) in features {
            if parts.len() != batch.len() {
                return Err(Error::Graph(format!(
                    "node feature {name}/{fname} missing from some batch elements"
                )));
            }
            merged.features.insert(fname.clone(), concat_features(&parts, &fname)?);
        }
        node_sets.insert(name.clone(), merged);
    }

    // Edge sets: concatenate and shift indices by per-graph node offsets.
    let mut edge_sets: BTreeMap<String, EdgeSet> = BTreeMap::new();
    for name in batch[0].edge_sets.keys() {
        let first = batch[0].edge_set(name)?;
        let (src_set, tgt_set) =
            (first.adjacency.source_set.clone(), first.adjacency.target_set.clone());
        let mut sizes = Vec::with_capacity(total_components);
        let mut source = Vec::new();
        let mut target = Vec::new();
        let mut features: BTreeMap<String, Vec<&Feature>> = BTreeMap::new();
        let mut src_off = 0u32;
        let mut tgt_off = 0u32;
        for g in batch {
            let es = g.edge_set(name)?;
            if es.adjacency.source_set != src_set || es.adjacency.target_set != tgt_set {
                return Err(Error::Graph(format!(
                    "edge set {name:?} endpoint mismatch across batch"
                )));
            }
            sizes.extend_from_slice(&es.sizes);
            source.extend(es.adjacency.source.iter().map(|&i| i + src_off));
            target.extend(es.adjacency.target.iter().map(|&i| i + tgt_off));
            for (fname, f) in &es.features {
                features.entry(fname.clone()).or_default().push(f);
            }
            src_off += g.num_nodes(&src_set)? as u32;
            tgt_off += g.num_nodes(&tgt_set)? as u32;
        }
        let mut merged = EdgeSet::new(
            sizes,
            Adjacency { source_set: src_set, target_set: tgt_set, source, target },
        );
        for (fname, parts) in features {
            if parts.len() != batch.len() {
                return Err(Error::Graph(format!(
                    "edge feature {name}/{fname} missing from some batch elements"
                )));
            }
            merged.features.insert(fname.clone(), concat_features(&parts, &fname)?);
        }
        edge_sets.insert(name.clone(), merged);
    }

    // Context: concatenate per-component rows.
    let mut context = Context::default();
    for fname in batch[0].context.features.keys() {
        let parts: Vec<&Feature> = batch
            .iter()
            .map(|g| g.context.feature(fname))
            .collect::<Result<Vec<_>>>()?;
        context.features.insert(fname.clone(), concat_features(&parts, fname)?);
    }

    let merged = GraphTensor { context, node_sets, edge_sets, num_components: total_components };
    merged.validate()?;
    Ok(merged)
}

/// Split a merged GraphTensor back into its components (inverse of
/// [`merge`] for single-component inputs).
pub fn split(graph: &GraphTensor) -> Result<Vec<GraphTensor>> {
    let mut out = Vec::with_capacity(graph.num_components);
    for c in 0..graph.num_components {
        let mut node_sets = BTreeMap::new();
        let mut node_offsets: BTreeMap<String, usize> = BTreeMap::new();
        for (name, ns) in &graph.node_sets {
            let before: usize = ns.sizes[..c].iter().sum();
            let n = ns.sizes[c];
            node_offsets.insert(name.clone(), before);
            let mut piece = NodeSet::new(vec![n]);
            for (fname, f) in &ns.features {
                piece.features.insert(fname.clone(), slice_feature(f, before, n));
            }
            node_sets.insert(name.clone(), piece);
        }
        let mut edge_sets = BTreeMap::new();
        for (name, es) in &graph.edge_sets {
            let before: usize = es.sizes[..c].iter().sum();
            let n = es.sizes[c];
            let src_off = node_offsets[&es.adjacency.source_set] as u32;
            let tgt_off = node_offsets[&es.adjacency.target_set] as u32;
            let mut piece = EdgeSet::new(
                vec![n],
                Adjacency {
                    source_set: es.adjacency.source_set.clone(),
                    target_set: es.adjacency.target_set.clone(),
                    source: es.adjacency.source[before..before + n]
                        .iter()
                        .map(|&i| i - src_off)
                        .collect(),
                    target: es.adjacency.target[before..before + n]
                        .iter()
                        .map(|&i| i - tgt_off)
                        .collect(),
                },
            );
            for (fname, f) in &es.features {
                piece.features.insert(fname.clone(), slice_feature(f, before, n));
            }
            edge_sets.insert(name.clone(), piece);
        }
        let mut context = Context::default();
        for (fname, f) in &graph.context.features {
            context.features.insert(fname.clone(), slice_feature(f, c, 1));
        }
        let g = GraphTensor { context, node_sets, edge_sets, num_components: 1 };
        g.validate()?;
        out.push(g);
    }
    Ok(out)
}

/// Concatenate features along the item dimension.
fn concat_features(parts: &[&Feature], name: &str) -> Result<Feature> {
    let first = parts[0];
    match first {
        Feature::F32 { dims, .. } => {
            let mut data = Vec::new();
            for p in parts {
                let (d, v) = p.as_f32()?;
                if d != dims.as_slice() {
                    return Err(Error::Feature(format!("feature {name:?}: dim mismatch in batch")));
                }
                data.extend_from_slice(v);
            }
            Ok(Feature::F32 { dims: dims.clone(), data })
        }
        Feature::I64 { dims, .. } => {
            let mut data = Vec::new();
            for p in parts {
                let (d, v) = p.as_i64()?;
                if d != dims.as_slice() {
                    return Err(Error::Feature(format!("feature {name:?}: dim mismatch in batch")));
                }
                data.extend_from_slice(v);
            }
            Ok(Feature::I64 { dims: dims.clone(), data })
        }
        Feature::Str { .. } => {
            let mut data = Vec::new();
            for p in parts {
                data.extend_from_slice(p.as_str()?);
            }
            Ok(Feature::Str { data })
        }
        Feature::RaggedF32 { .. } => {
            let mut row_splits = vec![0usize];
            let mut data = Vec::new();
            for p in parts {
                match p {
                    Feature::RaggedF32 { row_splits: rs, data: d } => {
                        let base = data.len();
                        data.extend_from_slice(d);
                        row_splits.extend(rs[1..].iter().map(|&s| s + base));
                    }
                    _ => {
                        return Err(Error::Feature(format!(
                            "feature {name:?}: mixed ragged/dense in batch"
                        )))
                    }
                }
            }
            Ok(Feature::RaggedF32 { row_splits, data })
        }
        Feature::RaggedI64 { .. } => {
            let mut row_splits = vec![0usize];
            let mut data = Vec::new();
            for p in parts {
                match p {
                    Feature::RaggedI64 { row_splits: rs, data: d } => {
                        let base = data.len();
                        data.extend_from_slice(d);
                        row_splits.extend(rs[1..].iter().map(|&s| s + base));
                    }
                    _ => {
                        return Err(Error::Feature(format!(
                            "feature {name:?}: mixed ragged/dense in batch"
                        )))
                    }
                }
            }
            Ok(Feature::RaggedI64 { row_splits, data })
        }
    }
}

/// Slice `n` items starting at `at` out of a feature.
fn slice_feature(f: &Feature, at: usize, n: usize) -> Feature {
    match f {
        Feature::F32 { dims, data } => {
            let per: usize = dims.iter().product::<usize>().max(1);
            Feature::F32 { dims: dims.clone(), data: data[at * per..(at + n) * per].to_vec() }
        }
        Feature::I64 { dims, data } => {
            let per: usize = dims.iter().product::<usize>().max(1);
            Feature::I64 { dims: dims.clone(), data: data[at * per..(at + n) * per].to_vec() }
        }
        Feature::Str { data } => Feature::Str { data: data[at..at + n].to_vec() },
        Feature::RaggedF32 { row_splits, data } => {
            let lo = row_splits[at];
            let hi = row_splits[at + n];
            Feature::RaggedF32 {
                row_splits: row_splits[at..=at + n].iter().map(|&s| s - lo).collect(),
                data: data[lo..hi].to_vec(),
            }
        }
        Feature::RaggedI64 { row_splits, data } => {
            let lo = row_splits[at];
            let hi = row_splits[at + n];
            Feature::RaggedI64 {
                row_splits: row_splits[at..=at + n].iter().map(|&s| s - lo).collect(),
                data: data[lo..hi].to_vec(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::recsys::recsys_example_graph;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn merge_two_recsys_graphs() {
        let g = recsys_example_graph().unwrap();
        let merged = merge(&[g.clone(), g.clone()]).unwrap();
        assert_eq!(merged.num_components, 2);
        assert_eq!(merged.num_nodes("items").unwrap(), 12);
        assert_eq!(merged.num_nodes("users").unwrap(), 8);
        assert_eq!(merged.num_edges("purchased").unwrap(), 14);
        // Second copy's edges shifted by the first copy's node counts.
        let es = merged.edge_set("purchased").unwrap();
        assert_eq!(es.adjacency.source[7], 0 + 6);
        assert_eq!(es.adjacency.target[7], 1 + 4);
        // Context rows stacked: one row per component.
        let scores = merged.context.feature("scores").unwrap();
        let (dims, data) = scores.as_f32().unwrap();
        assert_eq!(dims, &[4]);
        assert_eq!(data.len(), 8);
    }

    #[test]
    fn merge_then_split_roundtrips() {
        let g = recsys_example_graph().unwrap();
        let merged = merge(&[g.clone(), g.clone(), g.clone()]).unwrap();
        let parts = split(&merged).unwrap();
        assert_eq!(parts.len(), 3);
        for p in parts {
            assert_eq!(p, g);
        }
    }

    #[test]
    fn merge_single_is_identity_modulo_components() {
        let g = recsys_example_graph().unwrap();
        let merged = merge(&[g.clone()]).unwrap();
        assert_eq!(merged, g);
    }

    #[test]
    fn merge_empty_fails() {
        assert!(merge(&[]).is_err());
    }

    /// Random heterogeneous graph for property tests.
    pub fn random_graph(rng: &mut Rng) -> GraphTensor {
        let dim = 1 + rng.uniform(4);
        random_graph_with_dim(rng, dim)
    }

    /// Random graph with a fixed feature dim (so batches merge).
    pub fn random_graph_with_dim(rng: &mut Rng, dim: usize) -> GraphTensor {
        let n_a = 1 + rng.uniform(6);
        let n_b = 1 + rng.uniform(5);
        let e_ab = rng.uniform(8);
        let a = NodeSet::new(vec![n_a]).with_feature(
            "h",
            Feature::f32_mat(dim, (0..n_a * dim).map(|_| rng.f32()).collect()),
        );
        let b = NodeSet::new(vec![n_b]).with_feature(
            "h",
            Feature::f32_mat(dim, (0..n_b * dim).map(|_| rng.f32()).collect()),
        );
        let e = EdgeSet::new(
            vec![e_ab],
            Adjacency {
                source_set: "a".into(),
                target_set: "b".into(),
                source: (0..e_ab).map(|_| rng.uniform(n_a) as u32).collect(),
                target: (0..e_ab).map(|_| rng.uniform(n_b) as u32).collect(),
            },
        )
        .with_feature("w", Feature::f32_vec((0..e_ab).map(|_| rng.f32()).collect()));
        let ctx = Context::default().with_feature("label", Feature::i64_vec(vec![rng.uniform(10) as i64]));
        GraphTensor::from_pieces(
            ctx,
            [("a".to_string(), a), ("b".to_string(), b)].into(),
            [("e".to_string(), e)].into(),
        )
        .unwrap()
    }

    #[test]
    fn prop_merge_split_identity() {
        check("merge∘split = id", 50, |rng| {
            let k = 1 + rng.uniform(5);
            let dim = 1 + rng.uniform(4);
            let batch: Vec<GraphTensor> =
                (0..k).map(|_| random_graph_with_dim(rng, dim)).collect();
            let merged = merge(&batch).unwrap();
            merged.validate().unwrap();
            let parts = split(&merged).unwrap();
            assert_eq!(parts, batch);
        });
    }

    #[test]
    fn prop_merge_counts_additive() {
        check("merge adds node/edge counts", 50, |rng| {
            let k = 1 + rng.uniform(4);
            let dim = 1 + rng.uniform(4);
            let batch: Vec<GraphTensor> =
                (0..k).map(|_| random_graph_with_dim(rng, dim)).collect();
            let merged = merge(&batch).unwrap();
            let want_a: usize = batch.iter().map(|g| g.num_nodes("a").unwrap()).sum();
            let want_e: usize = batch.iter().map(|g| g.num_edges("e").unwrap()).sum();
            assert_eq!(merged.num_nodes("a").unwrap(), want_a);
            assert_eq!(merged.num_edges("e").unwrap(), want_e);
            assert_eq!(merged.num_components, k);
        });
    }

    #[test]
    fn prop_merge_associative_via_flatten() {
        check("merge(merge(x,y),z) == merge(x,y,z)", 30, |rng| {
            let dim = 1 + rng.uniform(4);
            let x = random_graph_with_dim(rng, dim);
            let y = random_graph_with_dim(rng, dim);
            let z = random_graph_with_dim(rng, dim);
            let left = merge(&[merge(&[x.clone(), y.clone()]).unwrap(), z.clone()]).unwrap();
            let flat = merge(&[x, y, z]).unwrap();
            assert_eq!(left, flat);
        });
    }

    #[test]
    fn ragged_features_merge() {
        let g = recsys_example_graph().unwrap();
        let merged = merge(&[g.clone(), g]).unwrap();
        let price = merged.node_set("items").unwrap().feature("price").unwrap();
        assert_eq!(price.len(), 12);
        assert_eq!(price.ragged_row_f32(6).unwrap(), &[22.34, 23.42, 12.99]);
    }
}

#[cfg(test)]
pub use tests::{random_graph, random_graph_with_dim};
