//! Fused broadcast→pool message passing (the §4.1 hot path).
//!
//! A GNN convolution's data exchange is `broadcast_node_to_edges`
//! followed by `pool_edges_to_node`: gather a sender-node value onto
//! every edge, then reduce per receiver node. Composed from the two
//! primitives, that materializes a `[num_edges, d]` intermediate and
//! walks the COO index arrays twice — exactly the overhead the paper's
//! Keras convolutions (and tf_geometric's fused CSR kernels) avoid
//! when no per-edge computation is required.
//!
//! [`broadcast_pool_fused`] performs the round trip in one pass over
//! the edge set's cached CSR view ([`GraphTensor::csr`]): for each
//! receiver node, gather directly from the sender-node values and
//! accumulate into the output row. No per-edge buffer exists at any
//! point. [`softmax_weighted_pool_fused`] does the same for the
//! attention pattern (§4.3): per-receiver softmax over edge logits,
//! then a weighted sum of sender values, with only an O(max-degree)
//! scratch buffer.
//!
//! **Bit-for-bit contract.** Both functions are drop-in replacements
//! for the unfused op sequence, asserted down to f32 bit patterns by
//! property tests: within a receiver row the CSR lists edge ids in
//! ascending order, which is exactly the order the unfused
//! `segment_*` oracle touches that receiver's edges, so every float
//! accumulation happens in the same sequence. The unfused path stays
//! in `ops` as the oracle (and for pipelines that *do* need the
//! per-edge tensor, e.g. to attach edge features).
//!
//! [`ParallelOps`] runs the same kernels sharded over receiver-node
//! ranges on the existing [`util::ThreadPool`](crate::util::threadpool)
//! — rows are independent, so the parallel output is identical (not
//! merely close) for every thread count.

use std::ops::Range;
use std::sync::Arc;

use super::{dense_f32, elems_per_item, Reduce, Tag};
use crate::graph::{Csr, Feature, GraphTensor};
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// Everything a kernel needs, resolved once per call: the CSR view
/// keyed by the receiver endpoint plus gather rules for the sender.
struct FusedPlan {
    csr: Arc<Csr>,
    /// Sender == receiver endpoint: gather from the row node itself
    /// (the CSR's `neighbors` hold the *opposite* endpoint).
    gather_self: bool,
    d: usize,
}

fn plan(
    g: &GraphTensor,
    edge_set: &str,
    send_tag: Tag,
    recv_tag: Tag,
    value: &Feature,
    what: &str,
) -> Result<(FusedPlan, Vec<usize>)> {
    let es = g.edge_set(edge_set)?;
    let send_set = match send_tag {
        Tag::Source => &es.adjacency.source_set,
        Tag::Target => &es.adjacency.target_set,
    };
    let n_send = g.num_nodes(send_set)?;
    if value.len() != n_send {
        return Err(Error::Feature(format!(
            "{what}: value has {} items, node set {send_set:?} has {n_send}",
            value.len()
        )));
    }
    let (dims, _) = dense_f32(value, what)?;
    let csr = g.csr(edge_set, recv_tag.incidence())?;
    let d = elems_per_item(dims);
    Ok((FusedPlan { csr, gather_self: send_tag == recv_tag, d }, dims.to_vec()))
}

/// [`plan`] plus the logits checks shared by the serial and parallel
/// softmax entry points (one scalar per edge, edge count match).
fn softmax_plan(
    g: &GraphTensor,
    edge_set: &str,
    send_tag: Tag,
    recv_tag: Tag,
    logits: &Feature,
    values: &Feature,
) -> Result<(FusedPlan, Vec<usize>)> {
    let (plan, dims) = plan(g, edge_set, send_tag, recv_tag, values, "softmax_weighted_pool_fused")?;
    let (ldims, _) = dense_f32(logits, "softmax_weighted_pool_fused logits")?;
    if elems_per_item(ldims) != 1 {
        return Err(Error::Feature(
            "softmax_weighted_pool_fused: logits must be one scalar per edge".into(),
        ));
    }
    if logits.len() != plan.csr.num_edges() {
        return Err(Error::Feature(format!(
            "softmax_weighted_pool_fused: {} logits for {} edges",
            logits.len(),
            plan.csr.num_edges()
        )));
    }
    Ok((plan, dims))
}

/// One fused broadcast→pool pass over `range` of receiver nodes,
/// writing `range.len() * d` output values. Kept free of `Feature`
/// plumbing so the serial and parallel paths share it verbatim.
fn pool_rows(plan: &FusedPlan, data: &[f32], reduce: Reduce, range: Range<usize>) -> Vec<f32> {
    let d = plan.d;
    let csr = &*plan.csr;
    let mut out = vec![0.0f32; range.len() * d];
    for (row_i, r) in range.enumerate() {
        let acc = &mut out[row_i * d..(row_i + 1) * d];
        let neighbors = csr.row_neighbors(r);
        match reduce {
            Reduce::Sum | Reduce::Mean => {
                for &v in neighbors {
                    let v = if plan.gather_self { r } else { v as usize };
                    let src = &data[v * d..(v + 1) * d];
                    for (o, x) in acc.iter_mut().zip(src) {
                        *o += x;
                    }
                }
                if reduce == Reduce::Mean && !neighbors.is_empty() {
                    // Same expression as segment_mean: one reciprocal,
                    // then a multiply — not a divide — per element.
                    let inv = 1.0 / neighbors.len() as f32;
                    for o in acc.iter_mut() {
                        *o *= inv;
                    }
                }
            }
            Reduce::Max | Reduce::Min => {
                if neighbors.is_empty() {
                    continue; // empty segments stay 0 (padded-graph rule)
                }
                let init =
                    if reduce == Reduce::Max { f32::NEG_INFINITY } else { f32::INFINITY };
                acc.fill(init);
                for &v in neighbors {
                    let v = if plan.gather_self { r } else { v as usize };
                    let src = &data[v * d..(v + 1) * d];
                    for (o, x) in acc.iter_mut().zip(src) {
                        // Mirrors segment_max/min exactly, including
                        // NaN stickiness.
                        let better = if reduce == Reduce::Max { *x > *o } else { *x < *o };
                        if x.is_nan() || (!o.is_nan() && better) {
                            *o = *x;
                        }
                    }
                }
            }
        }
    }
    out
}

/// One fused softmax→weighted-pool pass over `range` of receiver
/// nodes. `logits` is one scalar per edge (indexed by edge id);
/// `values` is the `[n_send, d]` sender-node value buffer.
fn softmax_pool_rows(
    plan: &FusedPlan,
    logits: &[f32],
    values: &[f32],
    range: Range<usize>,
) -> Vec<f32> {
    let d = plan.d;
    let csr = &*plan.csr;
    let mut out = vec![0.0f32; range.len() * d];
    let mut exps: Vec<f32> = Vec::new(); // O(max degree) scratch, reused
    for (row_i, r) in range.enumerate() {
        let edges = csr.row(r);
        if edges.is_empty() {
            continue;
        }
        // Pass 1: per-receiver max logit, in ascending edge order (the
        // same fold segment_softmax_values performs per segment).
        let mut m = f32::NEG_INFINITY;
        for &e in edges {
            let l = logits[e as usize];
            if l > m {
                m = l;
            }
        }
        // Pass 2: exp(l - max), accumulating the normalizer in order.
        exps.clear();
        let mut sum = 0.0f32;
        for &e in edges {
            let x = (logits[e as usize] - m).exp();
            exps.push(x);
            sum += x;
        }
        // Pass 3: weighted gather-accumulate from the sender values.
        let acc = &mut out[row_i * d..(row_i + 1) * d];
        for (k, &v) in csr.row_neighbors(r).iter().enumerate() {
            let w = exps[k] / sum;
            let v = if plan.gather_self { r } else { v as usize };
            let src = &values[v * d..(v + 1) * d];
            for (o, x) in acc.iter_mut().zip(src) {
                *o += w * x;
            }
        }
    }
    out
}

/// Fused `pool_edges_to_node(recv_tag, reduce,
/// broadcast_node_to_edges(send_tag, value))` — identical output
/// (bit-for-bit), no `[num_edges, d]` intermediate.
pub fn broadcast_pool_fused(
    g: &GraphTensor,
    edge_set: &str,
    send_tag: Tag,
    recv_tag: Tag,
    reduce: Reduce,
    value: &Feature,
) -> Result<Feature> {
    let (plan, dims) = plan(g, edge_set, send_tag, recv_tag, value, "broadcast_pool_fused")?;
    let (_, data) = dense_f32(value, "broadcast_pool_fused")?;
    let n_recv = plan.csr.num_nodes();
    let out = pool_rows(&plan, data, reduce, 0..n_recv);
    Ok(Feature::F32 { dims, data: out })
}

/// Fused attention aggregation: softmax the per-edge `logits` within
/// each `recv_tag` group (exactly [`segment_softmax`](super::segment_softmax)),
/// then sum-pool the softmax-weighted `send_tag` node values to the
/// receivers. Equals the unfused sequence bit-for-bit.
pub fn softmax_weighted_pool_fused(
    g: &GraphTensor,
    edge_set: &str,
    send_tag: Tag,
    recv_tag: Tag,
    logits: &Feature,
    values: &Feature,
) -> Result<Feature> {
    let (plan, dims) = softmax_plan(g, edge_set, send_tag, recv_tag, logits, values)?;
    let (_, data) = dense_f32(values, "softmax_weighted_pool_fused")?;
    let (_, ldata) = dense_f32(logits, "softmax_weighted_pool_fused logits")?;
    let n_recv = plan.csr.num_nodes();
    let out = softmax_pool_rows(&plan, ldata, data, 0..n_recv);
    Ok(Feature::F32 { dims, data: out })
}

/// The fused kernels sharded over receiver-node ranges on the shared
/// [`ThreadPool`]. Receiver rows are independent, so results are
/// identical to the serial fused path (and therefore to the unfused
/// oracle) for every worker count — asserted by property tests.
pub struct ParallelOps {
    pool: Arc<ThreadPool>,
}

impl ParallelOps {
    pub fn new(pool: Arc<ThreadPool>) -> ParallelOps {
        ParallelOps { pool }
    }

    /// Worker count of the underlying pool.
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Split `n` rows into ~4 chunks per worker (bounded by `n`) so
    /// skewed degree distributions still balance.
    fn chunks(&self, n: usize) -> Vec<(usize, usize)> {
        let target = (self.pool.size() * 4).clamp(1, n.max(1));
        let per = n.div_ceil(target);
        let mut out = Vec::new();
        let mut at = 0;
        while at < n {
            let end = (at + per).min(n);
            out.push((at, end));
            at = end;
        }
        out
    }

    /// Parallel [`broadcast_pool_fused`].
    pub fn broadcast_pool_fused(
        &self,
        g: &GraphTensor,
        edge_set: &str,
        send_tag: Tag,
        recv_tag: Tag,
        reduce: Reduce,
        value: &Feature,
    ) -> Result<Feature> {
        let (plan, dims) =
            plan(g, edge_set, send_tag, recv_tag, value, "broadcast_pool_fused")?;
        let (_, data) = dense_f32(value, "broadcast_pool_fused")?;
        let n_recv = plan.csr.num_nodes();
        // The pool requires 'static jobs; share the (node-sized, not
        // edge-sized) value buffer via one Arc copy.
        let data: Arc<Vec<f32>> = Arc::new(data.to_vec());
        let plan = Arc::new(plan);
        let chunks = self.chunks(n_recv);
        let parts = self.pool.map(chunks, {
            let plan = Arc::clone(&plan);
            let data = Arc::clone(&data);
            move |(s, e)| pool_rows(&plan, &data, reduce, s..e)
        });
        let mut out = Vec::with_capacity(n_recv * plan.d);
        for p in parts {
            out.extend_from_slice(&p);
        }
        Ok(Feature::F32 { dims, data: out })
    }

    /// Parallel [`softmax_weighted_pool_fused`].
    pub fn softmax_weighted_pool_fused(
        &self,
        g: &GraphTensor,
        edge_set: &str,
        send_tag: Tag,
        recv_tag: Tag,
        logits: &Feature,
        values: &Feature,
    ) -> Result<Feature> {
        let (plan, dims) = softmax_plan(g, edge_set, send_tag, recv_tag, logits, values)?;
        let (_, data) = dense_f32(values, "softmax_weighted_pool_fused")?;
        let (_, ldata) = dense_f32(logits, "softmax_weighted_pool_fused logits")?;
        let n_recv = plan.csr.num_nodes();
        let data: Arc<Vec<f32>> = Arc::new(data.to_vec());
        let ldata: Arc<Vec<f32>> = Arc::new(ldata.to_vec());
        let plan = Arc::new(plan);
        let chunks = self.chunks(n_recv);
        let parts = self.pool.map(chunks, {
            let plan = Arc::clone(&plan);
            let data = Arc::clone(&data);
            let ldata = Arc::clone(&ldata);
            move |(s, e)| softmax_pool_rows(&plan, &ldata, &data, s..e)
        });
        let mut out = Vec::with_capacity(n_recv * plan.d);
        for p in parts {
            out.extend_from_slice(&p);
        }
        Ok(Feature::F32 { dims, data: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Adjacency, Context, EdgeSet, GraphTensor, NodeSet};
    use crate::ops::{broadcast_node_to_edges, pool_edges_to_node, segment_softmax};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// Single-component graph over one node set "n" with `n_nodes`
    /// nodes and `n_edges` random edges in edge set "e".
    fn random_graph(rng: &mut Rng, n_nodes: usize, n_edges: usize) -> GraphTensor {
        let ns = NodeSet::new(vec![n_nodes]);
        let es = EdgeSet::new(
            vec![n_edges],
            Adjacency {
                source_set: "n".into(),
                target_set: "n".into(),
                source: (0..n_edges).map(|_| rng.uniform(n_nodes) as u32).collect(),
                target: (0..n_edges).map(|_| rng.uniform(n_nodes) as u32).collect(),
            },
        );
        GraphTensor::from_pieces(
            Context::default(),
            [("n".to_string(), ns)].into(),
            [("e".to_string(), es)].into(),
        )
        .unwrap()
    }

    /// The unfused reference: broadcast then pool.
    fn oracle(
        g: &GraphTensor,
        send: Tag,
        recv: Tag,
        reduce: Reduce,
        value: &Feature,
    ) -> Feature {
        let on_edges = broadcast_node_to_edges(g, "e", send, value).unwrap();
        pool_edges_to_node(g, "e", recv, reduce, &on_edges).unwrap()
    }

    fn assert_bits_eq(a: &Feature, b: &Feature, what: &str) {
        let (da, va) = a.as_f32().unwrap();
        let (db, vb) = b.as_f32().unwrap();
        assert_eq!(da, db, "{what}: dims");
        assert_eq!(va.len(), vb.len(), "{what}: len");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    const TAGS: [Tag; 2] = [Tag::Source, Tag::Target];
    const REDUCTIONS: [Reduce; 4] = [Reduce::Sum, Reduce::Mean, Reduce::Max, Reduce::Min];

    /// The acceptance property: fused == unfused, bit-for-bit, for all
    /// four reductions, all tag combinations, d ∈ 1..=8, and thread
    /// counts 1 / 2 / 8.
    #[test]
    fn prop_fused_matches_oracle_bitexact() {
        check("broadcast_pool_fused == broadcast+pool", 40, |rng| {
            let n_nodes = 1 + rng.uniform(24);
            let n_edges = rng.uniform(80);
            let d = 1 + rng.uniform(8);
            let g = random_graph(rng, n_nodes, n_edges);
            let value =
                Feature::f32_mat(d, (0..n_nodes * d).map(|_| rng.range_f32(-3.0, 3.0)).collect());
            let threads = [1usize, 2, 8].map(|t| ParallelOps::new(Arc::new(ThreadPool::new(t))));
            for send in TAGS {
                for recv in TAGS {
                    for reduce in REDUCTIONS {
                        let want = oracle(&g, send, recv, reduce, &value);
                        let got =
                            broadcast_pool_fused(&g, "e", send, recv, reduce, &value).unwrap();
                        assert_bits_eq(&want, &got, &format!("serial {send:?}->{recv:?} {reduce:?}"));
                        for par in &threads {
                            let got = par
                                .broadcast_pool_fused(&g, "e", send, recv, reduce, &value)
                                .unwrap();
                            assert_bits_eq(
                                &want,
                                &got,
                                &format!("{}t {send:?}->{recv:?} {reduce:?}", par.threads()),
                            );
                        }
                    }
                }
            }
        });
    }

    /// Same property with non-finite values present: ±inf and NaN flow
    /// through both paths identically.
    #[test]
    fn prop_fused_matches_oracle_nonfinite() {
        check("fused handles ±inf / NaN like the oracle", 25, |rng| {
            let n_nodes = 1 + rng.uniform(12);
            let n_edges = rng.uniform(40);
            let d = 1 + rng.uniform(4);
            let g = random_graph(rng, n_nodes, n_edges);
            let value = Feature::f32_mat(
                d,
                (0..n_nodes * d)
                    .map(|_| match rng.uniform(10) {
                        0 => f32::INFINITY,
                        1 => f32::NEG_INFINITY,
                        2 => f32::NAN,
                        _ => rng.range_f32(-2.0, 2.0),
                    })
                    .collect(),
            );
            for reduce in REDUCTIONS {
                let want = oracle(&g, Tag::Source, Tag::Target, reduce, &value);
                let got =
                    broadcast_pool_fused(&g, "e", Tag::Source, Tag::Target, reduce, &value)
                        .unwrap();
                assert_bits_eq(&want, &got, &format!("nonfinite {reduce:?}"));
            }
        });
    }

    #[test]
    fn prop_softmax_pool_matches_oracle_bitexact() {
        check("softmax_weighted_pool_fused == softmax+mul+pool", 40, |rng| {
            let n_nodes = 1 + rng.uniform(24);
            let n_edges = rng.uniform(80);
            let d = 1 + rng.uniform(8);
            let g = random_graph(rng, n_nodes, n_edges);
            let values =
                Feature::f32_mat(d, (0..n_nodes * d).map(|_| rng.range_f32(-3.0, 3.0)).collect());
            let logits =
                Feature::f32_vec((0..n_edges).map(|_| rng.range_f32(-6.0, 6.0)).collect());
            let threads = [1usize, 2, 8].map(|t| ParallelOps::new(Arc::new(ThreadPool::new(t))));
            for send in TAGS {
                for recv in TAGS {
                    // Unfused oracle: weights, broadcast, scale, pool.
                    let w = segment_softmax(&g, "e", recv, &logits).unwrap();
                    let (_, wv) = w.as_f32().unwrap();
                    let msgs = broadcast_node_to_edges(&g, "e", send, &values).unwrap();
                    let (mdims, mv) = msgs.as_f32().unwrap();
                    let weighted = Feature::F32 {
                        dims: mdims.to_vec(),
                        data: mv
                            .iter()
                            .enumerate()
                            .map(|(i, &x)| wv[i / d] * x)
                            .collect(),
                    };
                    let want =
                        pool_edges_to_node(&g, "e", recv, Reduce::Sum, &weighted).unwrap();
                    let got = softmax_weighted_pool_fused(&g, "e", send, recv, &logits, &values)
                        .unwrap();
                    assert_bits_eq(&want, &got, &format!("serial softmax {send:?}->{recv:?}"));
                    for par in &threads {
                        let got = par
                            .softmax_weighted_pool_fused(&g, "e", send, recv, &logits, &values)
                            .unwrap();
                        assert_bits_eq(
                            &want,
                            &got,
                            &format!("{}t softmax {send:?}->{recv:?}", par.threads()),
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn fused_on_recsys_example() {
        // The A.3 spending computation through the fused path.
        let g = crate::synth::recsys::recsys_example_graph().unwrap();
        let price = g.node_set("items").unwrap().feature("price").unwrap().clone();
        let latest: Vec<f32> = (0..6).map(|i| price.ragged_row_f32(i).unwrap()[0]).collect();
        let latest = Feature::f32_vec(latest);
        let spending =
            broadcast_pool_fused(&g, "purchased", Tag::Source, Tag::Target, Reduce::Sum, &latest)
                .unwrap();
        let (_, sp) = spending.as_f32().unwrap();
        assert!((sp[0] - (89.99 + 24.99 + 45.13)).abs() < 1e-4);
        assert!((sp[1] - (22.34 + 27.99)).abs() < 1e-4);
        assert!((sp[2] - 350.0).abs() < 1e-4);
        assert!((sp[3] - 45.13).abs() < 1e-4);
    }

    #[test]
    fn fused_uses_memoized_csr() {
        let g = crate::synth::recsys::recsys_example_graph().unwrap();
        let es = g.edge_set("purchased").unwrap();
        assert!(!es.csr.is_built(crate::graph::Incidence::ByTarget));
        let v = Feature::f32_vec(vec![1.0; 6]);
        let _ =
            broadcast_pool_fused(&g, "purchased", Tag::Source, Tag::Target, Reduce::Sum, &v)
                .unwrap();
        assert!(
            g.edge_set("purchased").unwrap().csr.is_built(crate::graph::Incidence::ByTarget),
            "first fused call builds + memoizes the CSR view"
        );
    }

    #[test]
    fn fused_rejects_bad_shapes() {
        let g = crate::synth::recsys::recsys_example_graph().unwrap();
        let wrong = Feature::f32_vec(vec![1.0; 5]);
        assert!(broadcast_pool_fused(&g, "purchased", Tag::Source, Tag::Target, Reduce::Sum, &wrong)
            .is_err());
        let v = Feature::f32_vec(vec![1.0; 6]);
        let bad_logits = Feature::f32_vec(vec![0.0; 3]); // 7 edges
        assert!(softmax_weighted_pool_fused(
            &g,
            "purchased",
            Tag::Source,
            Tag::Target,
            &bad_logits,
            &v
        )
        .is_err());
    }
}
