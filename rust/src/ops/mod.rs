//! Data-exchange operations (paper §4.1, API Level 2).
//!
//! *Broadcasting* carries a value from a node set onto each incident
//! edge of an edge set; *pooling* aggregates per-edge values onto the
//! nodes at a chosen endpoint (sum / mean / max / min). The same pair of
//! operations connects the graph *context* with the nodes or edges of
//! each component. Unlike adjacency-matrix multiplication, these
//! primitives leave a natural place for per-edge computation — attention
//! logits, edge features, edge hidden states (§4.1).
//!
//! These Rust implementations serve three roles:
//! 1. feature engineering in the input pipeline (A.3's user-spending
//!    example runs on them),
//! 2. the **oracle** for integration tests against the AOT-compiled
//!    L2/L1 programs (both sides must agree bit-for-bit on sums),
//! 3. the reference semantics for the Pallas kernels' segment ops.
//!
//! Values are dense-f32 [`Feature`]s; ops accept either a stored feature
//! (by name) or an unstored value tensor, mirroring
//! `feature_name=` / `feature_value=` in the TF-GNN API.

mod fused;
pub mod model_ref;
mod segment;

pub use fused::{broadcast_pool_fused, softmax_weighted_pool_fused, ParallelOps};
pub use segment::{
    segment_max, segment_mean, segment_min, segment_softmax_values, segment_sum,
};

use crate::graph::{Feature, GraphTensor, Incidence};
use crate::{Error, Result};

/// Edge endpoint selector (tfgnn.SOURCE / tfgnn.TARGET).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    Source,
    Target,
}

impl Tag {
    /// The CSR incidence keyed by this endpoint.
    pub fn incidence(self) -> Incidence {
        match self {
            Tag::Source => Incidence::BySource,
            Tag::Target => Incidence::ByTarget,
        }
    }
}

/// Pooling reduction type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    Sum,
    Mean,
    Max,
    Min,
}

impl Reduce {
    pub fn name(&self) -> &'static str {
        match self {
            Reduce::Sum => "sum",
            Reduce::Mean => "mean",
            Reduce::Max => "max",
            Reduce::Min => "min",
        }
    }

    pub fn from_name(s: &str) -> Result<Reduce> {
        match s {
            "sum" => Ok(Reduce::Sum),
            "mean" => Ok(Reduce::Mean),
            "max" => Ok(Reduce::Max),
            "min" => Ok(Reduce::Min),
            other => Err(Error::Graph(format!("unknown reduce type {other:?}"))),
        }
    }
}

fn dense_f32<'a>(value: &'a Feature, what: &str) -> Result<(&'a [usize], &'a [f32])> {
    value
        .as_f32()
        .map_err(|_| Error::Feature(format!("{what}: ops require dense f32 values")))
}

fn elems_per_item(dims: &[usize]) -> usize {
    dims.iter().product::<usize>().max(1)
}

/// Guard against corrupt adjacency: every segment id must address a
/// real node, otherwise downstream slice arithmetic panics. Graphs
/// that went through [`GraphTensor::validate`] can't trip this, but
/// ops also run on hand-built / deserialized-in-parts tensors, so the
/// hot-path entry points check once and fail with [`Error::Graph`].
fn check_indices(edge_set: &str, tag: Tag, indices: &[u32], n_nodes: usize) -> Result<()> {
    if let Some((e, &i)) = indices.iter().enumerate().find(|&(_, &i)| i as usize >= n_nodes) {
        return Err(Error::Graph(format!(
            "edge set {edge_set:?}: {tag:?} index {i} at edge {e} out of range \
             (node set has {n_nodes} nodes)"
        )));
    }
    Ok(())
}

/// `tfgnn.broadcast_node_to_edges`: for each edge, the value at its
/// `tag` endpoint node.
pub fn broadcast_node_to_edges(
    g: &GraphTensor,
    edge_set: &str,
    tag: Tag,
    value: &Feature,
) -> Result<Feature> {
    let es = g.edge_set(edge_set)?;
    let indices = match tag {
        Tag::Source => &es.adjacency.source,
        Tag::Target => &es.adjacency.target,
    };
    let node_set = match tag {
        Tag::Source => &es.adjacency.source_set,
        Tag::Target => &es.adjacency.target_set,
    };
    let n_nodes = g.num_nodes(node_set)?;
    let (dims, data) = dense_f32(value, "broadcast_node_to_edges")?;
    if value.len() != n_nodes {
        return Err(Error::Feature(format!(
            "broadcast_node_to_edges: value has {} items, node set {node_set:?} has {n_nodes}",
            value.len()
        )));
    }
    check_indices(edge_set, tag, indices, n_nodes)?;
    let d = elems_per_item(dims);
    let mut out = Vec::with_capacity(indices.len() * d);
    for &i in indices {
        let i = i as usize;
        out.extend_from_slice(&data[i * d..(i + 1) * d]);
    }
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// Convenience overload taking a stored node feature by name.
pub fn broadcast_node_feature(
    g: &GraphTensor,
    edge_set: &str,
    tag: Tag,
    feature_name: &str,
) -> Result<Feature> {
    let es = g.edge_set(edge_set)?;
    let node_set = match tag {
        Tag::Source => es.adjacency.source_set.clone(),
        Tag::Target => es.adjacency.target_set.clone(),
    };
    let f = g.node_set(&node_set)?.feature(feature_name)?.clone();
    broadcast_node_to_edges(g, edge_set, tag, &f)
}

/// `tfgnn.pool_edges_to_node`: aggregate per-edge values at the `tag`
/// endpoint. Empty segments (nodes with no incident edges) yield 0.
pub fn pool_edges_to_node(
    g: &GraphTensor,
    edge_set: &str,
    tag: Tag,
    reduce: Reduce,
    value: &Feature,
) -> Result<Feature> {
    let es = g.edge_set(edge_set)?;
    let indices = match tag {
        Tag::Source => &es.adjacency.source,
        Tag::Target => &es.adjacency.target,
    };
    let node_set = match tag {
        Tag::Source => &es.adjacency.source_set,
        Tag::Target => &es.adjacency.target_set,
    };
    let n_nodes = g.num_nodes(node_set)?;
    let (dims, data) = dense_f32(value, "pool_edges_to_node")?;
    if value.len() != es.total() {
        return Err(Error::Feature(format!(
            "pool_edges_to_node: value has {} items, edge set {edge_set:?} has {}",
            value.len(),
            es.total()
        )));
    }
    check_indices(edge_set, tag, indices, n_nodes)?;
    let d = elems_per_item(dims);
    let out = match reduce {
        Reduce::Sum => segment_sum(data, indices, n_nodes, d),
        Reduce::Mean => segment_mean(data, indices, n_nodes, d),
        Reduce::Max => segment_max(data, indices, n_nodes, d),
        Reduce::Min => segment_min(data, indices, n_nodes, d),
    };
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// Per-node component id for a node set (derived from sizes).
pub fn node_component_ids(g: &GraphTensor, node_set: &str) -> Result<Vec<u32>> {
    let ns = g.node_set(node_set)?;
    let mut out = Vec::with_capacity(ns.total());
    for (c, &n) in ns.sizes.iter().enumerate() {
        out.extend(std::iter::repeat(c as u32).take(n));
    }
    Ok(out)
}

/// Per-edge component id for an edge set.
pub fn edge_component_ids(g: &GraphTensor, edge_set: &str) -> Result<Vec<u32>> {
    let es = g.edge_set(edge_set)?;
    let mut out = Vec::with_capacity(es.total());
    for (c, &n) in es.sizes.iter().enumerate() {
        out.extend(std::iter::repeat(c as u32).take(n));
    }
    Ok(out)
}

/// `tfgnn.pool_nodes_to_context`: aggregate node values per component.
pub fn pool_nodes_to_context(
    g: &GraphTensor,
    node_set: &str,
    reduce: Reduce,
    value: &Feature,
) -> Result<Feature> {
    let (dims, data) = dense_f32(value, "pool_nodes_to_context")?;
    if value.len() != g.num_nodes(node_set)? {
        return Err(Error::Feature("pool_nodes_to_context: item count mismatch".into()));
    }
    let ids = node_component_ids(g, node_set)?;
    let d = elems_per_item(dims);
    let out = match reduce {
        Reduce::Sum => segment_sum(data, &ids, g.num_components, d),
        Reduce::Mean => segment_mean(data, &ids, g.num_components, d),
        Reduce::Max => segment_max(data, &ids, g.num_components, d),
        Reduce::Min => segment_min(data, &ids, g.num_components, d),
    };
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// `tfgnn.broadcast_context_to_nodes`: each node receives its
/// component's context value.
pub fn broadcast_context_to_nodes(
    g: &GraphTensor,
    node_set: &str,
    value: &Feature,
) -> Result<Feature> {
    let (dims, data) = dense_f32(value, "broadcast_context_to_nodes")?;
    if value.len() != g.num_components {
        return Err(Error::Feature(format!(
            "broadcast_context_to_nodes: value has {} rows, graph has {} components",
            value.len(),
            g.num_components
        )));
    }
    let ids = node_component_ids(g, node_set)?;
    let d = elems_per_item(dims);
    let mut out = Vec::with_capacity(ids.len() * d);
    for &c in &ids {
        let c = c as usize;
        out.extend_from_slice(&data[c * d..(c + 1) * d]);
    }
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// `tfgnn.pool_edges_to_context`.
pub fn pool_edges_to_context(
    g: &GraphTensor,
    edge_set: &str,
    reduce: Reduce,
    value: &Feature,
) -> Result<Feature> {
    let (dims, data) = dense_f32(value, "pool_edges_to_context")?;
    if value.len() != g.num_edges(edge_set)? {
        return Err(Error::Feature("pool_edges_to_context: item count mismatch".into()));
    }
    let ids = edge_component_ids(g, edge_set)?;
    let d = elems_per_item(dims);
    let out = match reduce {
        Reduce::Sum => segment_sum(data, &ids, g.num_components, d),
        Reduce::Mean => segment_mean(data, &ids, g.num_components, d),
        Reduce::Max => segment_max(data, &ids, g.num_components, d),
        Reduce::Min => segment_min(data, &ids, g.num_components, d),
    };
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// `tfgnn.broadcast_context_to_edges`.
pub fn broadcast_context_to_edges(
    g: &GraphTensor,
    edge_set: &str,
    value: &Feature,
) -> Result<Feature> {
    let (dims, data) = dense_f32(value, "broadcast_context_to_edges")?;
    if value.len() != g.num_components {
        return Err(Error::Feature("broadcast_context_to_edges: component mismatch".into()));
    }
    let ids = edge_component_ids(g, edge_set)?;
    let d = elems_per_item(dims);
    let mut out = Vec::with_capacity(ids.len() * d);
    for &c in &ids {
        let c = c as usize;
        out.extend_from_slice(&data[c * d..(c + 1) * d]);
    }
    Ok(Feature::F32 { dims: dims.to_vec(), data: out })
}

/// `tfgnn.softmax` over edges grouped by their `tag` endpoint — the
/// attention-weights primitive (§4.3, A.4).
pub fn segment_softmax(
    g: &GraphTensor,
    edge_set: &str,
    tag: Tag,
    logits: &Feature,
) -> Result<Feature> {
    let es = g.edge_set(edge_set)?;
    let indices = match tag {
        Tag::Source => &es.adjacency.source,
        Tag::Target => &es.adjacency.target,
    };
    let node_set = match tag {
        Tag::Source => &es.adjacency.source_set,
        Tag::Target => &es.adjacency.target_set,
    };
    let n_nodes = g.num_nodes(node_set)?;
    let (dims, data) = dense_f32(logits, "segment_softmax")?;
    if logits.len() != es.total() {
        return Err(Error::Feature("segment_softmax: logits count mismatch".into()));
    }
    check_indices(edge_set, tag, indices, n_nodes)?;
    let d = elems_per_item(dims);
    Ok(Feature::F32 {
        dims: dims.to_vec(),
        data: segment_softmax_values(data, indices, n_nodes, d),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::recsys::recsys_example_graph;

    /// The appendix A.3 worked example: total user spending via
    /// broadcast + sum-pool, then fraction of max via context ops.
    #[test]
    fn a3_user_spending() {
        let g = recsys_example_graph().unwrap();
        // latest_price = price[:, :1] per item.
        let price = g.node_set("items").unwrap().feature("price").unwrap().clone();
        let latest: Vec<f32> = (0..6).map(|i| price.ragged_row_f32(i).unwrap()[0]).collect();
        let latest = Feature::f32_vec(latest);
        // purchase price per edge = broadcast from item (SOURCE).
        let purchase = broadcast_node_to_edges(&g, "purchased", Tag::Source, &latest).unwrap();
        let (_, pp) = purchase.as_f32().unwrap();
        assert_eq!(pp.len(), 7);
        assert_eq!(pp[4], 350.0); // the flight edge
        // total user spending = sum-pool to users (TARGET).
        let spending =
            pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Sum, &purchase).unwrap();
        let (_, sp) = spending.as_f32().unwrap();
        // users: Shawn(0): shoes 89.99 + book 24.99 + groceries 45.13
        //        Jeorg(1): food 22.34 + ticket 27.99
        //        Yumiko(2): flight 350.0, Sophie(3): groceries 45.13
        assert!((sp[0] - (89.99 + 24.99 + 45.13)).abs() < 1e-4, "{}", sp[0]);
        assert!((sp[1] - (22.34 + 27.99)).abs() < 1e-4);
        assert!((sp[2] - 350.0).abs() < 1e-4);
        assert!((sp[3] - 45.13).abs() < 1e-4);
        // max over users, broadcast back, fraction.
        let maxv = pool_nodes_to_context(&g, "users", Reduce::Max, &spending).unwrap();
        let (_, mv) = maxv.as_f32().unwrap();
        assert!((mv[0] - 350.0).abs() < 1e-4);
        let back = broadcast_context_to_nodes(&g, "users", &maxv).unwrap();
        let (_, bk) = back.as_f32().unwrap();
        assert_eq!(bk.len(), 4);
        assert!(bk.iter().all(|&x| (x - 350.0).abs() < 1e-4));
    }

    #[test]
    fn mean_max_min_pooling() {
        let g = recsys_example_graph().unwrap();
        let ones = Feature::f32_vec(vec![1.0; 7]);
        let mean = pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Mean, &ones).unwrap();
        let (_, m) = mean.as_f32().unwrap();
        assert_eq!(m, &[1.0, 1.0, 1.0, 1.0]);
        let vals = Feature::f32_vec(vec![3.0, 1.0, 5.0, 2.0, 7.0, 4.0, 6.0]);
        let mx = pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Max, &vals).unwrap();
        let (_, mx) = mx.as_f32().unwrap();
        // user0 receives edges 2,3,6 -> max(5,2,6)=6 ; user1 edges 0,1 -> 3
        assert_eq!(mx, &[6.0, 3.0, 7.0, 4.0]);
        let mn = pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Min, &vals).unwrap();
        let (_, mn) = mn.as_f32().unwrap();
        assert_eq!(mn, &[2.0, 1.0, 7.0, 4.0]);
    }

    #[test]
    fn empty_segments_are_zero() {
        let g = recsys_example_graph().unwrap();
        // "items" as SOURCE of purchased: item 4 appears once, all items
        // appear; instead pool over is-friend TARGET: only user 0
        // receives, users 1-3 get zeros.
        let vals = Feature::f32_vec(vec![1.0, 2.0, 3.0]);
        let pooled =
            pool_edges_to_node(&g, "is-friend", Tag::Target, Reduce::Sum, &vals).unwrap();
        let (_, p) = pooled.as_f32().unwrap();
        assert_eq!(p, &[6.0, 0.0, 0.0, 0.0]);
        let pooled_max =
            pool_edges_to_node(&g, "is-friend", Tag::Target, Reduce::Max, &vals).unwrap();
        let (_, p) = pooled_max.as_f32().unwrap();
        assert_eq!(p, &[3.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn vector_valued_broadcast_pool() {
        let g = recsys_example_graph().unwrap();
        // 2-d vectors on users, broadcast to is-friend source then pool back.
        let v = Feature::f32_mat(2, (0..8).map(|x| x as f32).collect());
        let on_edges = broadcast_node_to_edges(&g, "is-friend", Tag::Source, &v).unwrap();
        let (dims, d) = on_edges.as_f32().unwrap();
        assert_eq!(dims, &[2]);
        // edges sources = [1,2,3] -> rows [2,3],[4,5],[6,7]
        assert_eq!(d, &[2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let back = pool_edges_to_node(&g, "is-friend", Tag::Target, Reduce::Sum, &on_edges).unwrap();
        let (_, b) = back.as_f32().unwrap();
        assert_eq!(&b[0..2], &[12.0, 15.0]); // sum of the three rows at user 0
        assert!(b[2..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn softmax_normalizes_per_receiver() {
        let g = recsys_example_graph().unwrap();
        let logits = Feature::f32_vec(vec![0.0, 0.0, 1.0, 2.0, 0.5, 0.5, 3.0]);
        let w = segment_softmax(&g, "purchased", Tag::Target, &logits).unwrap();
        let (_, w) = w.as_f32().unwrap();
        // Receivers: user1 gets edges {0,1}, user0 gets {2,3,6}, user2 {4}, user3 {5}.
        assert!((w[0] - 0.5).abs() < 1e-6);
        assert!((w[1] - 0.5).abs() < 1e-6);
        let u0: f32 = w[2] + w[3] + w[6];
        assert!((u0 - 1.0).abs() < 1e-6);
        assert!(w[6] > w[3] && w[3] > w[2], "monotone in logits");
        assert!((w[4] - 1.0).abs() < 1e-6);
        assert!((w[5] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let g = recsys_example_graph().unwrap();
        let wrong = Feature::f32_vec(vec![1.0; 5]);
        assert!(broadcast_node_to_edges(&g, "purchased", Tag::Source, &wrong).is_err());
        assert!(pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Sum, &wrong).is_err());
        assert!(broadcast_context_to_nodes(&g, "users", &wrong).is_err());
        let int_feature = Feature::i64_vec(vec![1, 2, 3, 4, 5, 6]);
        assert!(broadcast_node_to_edges(&g, "purchased", Tag::Source, &int_feature).is_err());
    }

    /// Regression: out-of-range segment ids used to cause slice panics
    /// deep inside the segment kernels; they are now a proper
    /// `Error::Graph` (ops can see hand-built graphs that never went
    /// through `GraphTensor::validate`).
    #[test]
    fn corrupt_adjacency_is_an_error_not_a_panic() {
        let mut g = recsys_example_graph().unwrap();
        g.edge_sets.get_mut("purchased").unwrap().adjacency.target[3] = 99;
        let vals = Feature::f32_vec(vec![1.0; 7]);
        let err = pool_edges_to_node(&g, "purchased", Tag::Target, Reduce::Sum, &vals)
            .unwrap_err()
            .to_string();
        assert!(err.contains("graph error"), "{err}");
        assert!(err.contains("edge 3"), "{err}");
        let node_vals = Feature::f32_vec(vec![1.0; 4]);
        assert!(broadcast_node_to_edges(&g, "purchased", Tag::Target, &node_vals).is_err());
        assert!(segment_softmax(&g, "purchased", Tag::Target, &vals).is_err());
        // The fused path reports it too (via the CSR build).
        let item_vals = Feature::f32_vec(vec![1.0; 6]);
        assert!(broadcast_pool_fused(
            &g,
            "purchased",
            Tag::Source,
            Tag::Target,
            Reduce::Sum,
            &item_vals
        )
        .is_err());
    }

    #[test]
    fn component_ids() {
        let g = recsys_example_graph().unwrap();
        let merged = crate::graph::batch::merge(&[g.clone(), g]).unwrap();
        let ids = node_component_ids(&merged, "users").unwrap();
        assert_eq!(ids, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        let eids = edge_component_ids(&merged, "is-friend").unwrap();
        assert_eq!(eids, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn context_ops_multi_component() {
        let g = recsys_example_graph().unwrap();
        let merged = crate::graph::batch::merge(&[g.clone(), g]).unwrap();
        let vals = Feature::f32_vec((0..8).map(|x| x as f32).collect());
        let pooled = pool_nodes_to_context(&merged, "users", Reduce::Sum, &vals).unwrap();
        let (_, p) = pooled.as_f32().unwrap();
        assert_eq!(p, &[0.0 + 1.0 + 2.0 + 3.0, 4.0 + 5.0 + 6.0 + 7.0]);
        let bc = broadcast_context_to_edges(&merged, "is-friend", &pooled).unwrap();
        let (_, b) = bc.as_f32().unwrap();
        assert_eq!(b, &[6.0, 6.0, 6.0, 22.0, 22.0, 22.0]);
    }
}
