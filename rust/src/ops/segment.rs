//! Segment reductions over flat `[n, d]` buffers.
//!
//! These are the scalar reference semantics for both the Rust ops layer
//! and the Pallas kernels (whose pytest oracle `ref.py` mirrors them).
//! `segments` maps each of the `n` items to a segment id `< num_segments`;
//! `d` is the per-item element count.

/// Sum per segment; empty segments yield 0.
pub fn segment_sum(data: &[f32], segments: &[u32], num_segments: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), segments.len() * d);
    let mut out = vec![0.0f32; num_segments * d];
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        let src = &data[i * d..(i + 1) * d];
        let dst = &mut out[s * d..(s + 1) * d];
        for (o, v) in dst.iter_mut().zip(src) {
            *o += v;
        }
    }
    out
}

/// Mean per segment; empty segments yield 0.
pub fn segment_mean(data: &[f32], segments: &[u32], num_segments: usize, d: usize) -> Vec<f32> {
    let mut out = segment_sum(data, segments, num_segments, d);
    let mut counts = vec![0u32; num_segments];
    for &s in segments {
        counts[s as usize] += 1;
    }
    for (s, &c) in counts.iter().enumerate() {
        if c > 0 {
            let inv = 1.0 / c as f32;
            for v in &mut out[s * d..(s + 1) * d] {
                *v *= inv;
            }
        }
    }
    out
}

/// Max per segment; empty segments yield 0 (TF-GNN's default output for
/// missing inputs in `pool` with max is the dtype min; we clamp empties
/// to 0 so padded graphs stay finite — documented deviation, asserted in
/// tests on both sides of the AOT boundary).
///
/// Only *empty* segments are clamped: legitimate `±inf` inputs pass
/// through, and a NaN input makes its segment NaN (sticky, like a
/// sequential `reduce_max` over the segment). An earlier version
/// zeroed every non-finite output, silently rewriting real data.
pub fn segment_max(data: &[f32], segments: &[u32], num_segments: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), segments.len() * d);
    let mut out = vec![f32::NEG_INFINITY; num_segments * d];
    let mut counts = vec![0u32; num_segments];
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        counts[s] += 1;
        let src = &data[i * d..(i + 1) * d];
        let dst = &mut out[s * d..(s + 1) * d];
        for (o, v) in dst.iter_mut().zip(src) {
            if v.is_nan() || (!o.is_nan() && *v > *o) {
                *o = *v;
            }
        }
    }
    zero_empty_segments(&mut out, &counts, d);
    out
}

/// Min per segment; empty segments yield 0 (same clamping rules as
/// [`segment_max`]).
pub fn segment_min(data: &[f32], segments: &[u32], num_segments: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), segments.len() * d);
    let mut out = vec![f32::INFINITY; num_segments * d];
    let mut counts = vec![0u32; num_segments];
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        counts[s] += 1;
        let src = &data[i * d..(i + 1) * d];
        let dst = &mut out[s * d..(s + 1) * d];
        for (o, v) in dst.iter_mut().zip(src) {
            if v.is_nan() || (!o.is_nan() && *v < *o) {
                *o = *v;
            }
        }
    }
    zero_empty_segments(&mut out, &counts, d);
    out
}

/// Overwrite the rows of segments with no contributing items with 0
/// (the padded-graph deviation documented on [`segment_max`]).
fn zero_empty_segments(out: &mut [f32], counts: &[u32], d: usize) {
    for (s, &c) in counts.iter().enumerate() {
        if c == 0 {
            for v in &mut out[s * d..(s + 1) * d] {
                *v = 0.0;
            }
        }
    }
}

/// Numerically stable softmax within each segment (per element column):
/// subtracts the per-segment max before exponentiation.
pub fn segment_softmax_values(
    logits: &[f32],
    segments: &[u32],
    num_segments: usize,
    d: usize,
) -> Vec<f32> {
    debug_assert_eq!(logits.len(), segments.len() * d);
    // Per-segment max (for stability).
    let mut maxs = vec![f32::NEG_INFINITY; num_segments * d];
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        for k in 0..d {
            let v = logits[i * d + k];
            if v > maxs[s * d + k] {
                maxs[s * d + k] = v;
            }
        }
    }
    // exp(x - max), accumulate sums.
    let mut out = vec![0.0f32; logits.len()];
    let mut sums = vec![0.0f32; num_segments * d];
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        for k in 0..d {
            let e = (logits[i * d + k] - maxs[s * d + k]).exp();
            out[i * d + k] = e;
            sums[s * d + k] += e;
        }
    }
    for (i, &s) in segments.iter().enumerate() {
        let s = s as usize;
        for k in 0..d {
            out[i * d + k] /= sums[s * d + k];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn sum_basic() {
        let data = [1.0, 2.0, 3.0, 4.0];
        let seg = [0, 1, 0, 2];
        assert_eq!(segment_sum(&data, &seg, 3, 1), vec![4.0, 2.0, 4.0]);
    }

    #[test]
    fn sum_vector_valued() {
        let data = [1.0, 10.0, 2.0, 20.0, 3.0, 30.0];
        let seg = [1, 1, 0];
        assert_eq!(segment_sum(&data, &seg, 2, 2), vec![3.0, 30.0, 3.0, 30.0]);
    }

    #[test]
    fn mean_ignores_empty() {
        let data = [2.0, 4.0];
        let seg = [0, 0];
        assert_eq!(segment_mean(&data, &seg, 2, 1), vec![3.0, 0.0]);
    }

    #[test]
    fn max_min_with_negatives() {
        let data = [-5.0, -1.0, -3.0];
        let seg = [0, 0, 1];
        assert_eq!(segment_max(&data, &seg, 3, 1), vec![-1.0, -3.0, 0.0]);
        assert_eq!(segment_min(&data, &seg, 3, 1), vec![-5.0, -3.0, 0.0]);
    }

    /// Regression: non-finite *inputs* must survive max/min pooling;
    /// only empty segments are clamped to 0.
    #[test]
    fn max_min_preserve_infinities() {
        let data = [f32::INFINITY, 1.0, f32::NEG_INFINITY, 2.0];
        let seg = [0, 0, 1, 1];
        // Segment 2 is empty -> 0 on both sides (padded-graph deviation).
        assert_eq!(segment_max(&data, &seg, 3, 1), vec![f32::INFINITY, 2.0, 0.0]);
        assert_eq!(segment_min(&data, &seg, 3, 1), vec![1.0, f32::NEG_INFINITY, 0.0]);
    }

    #[test]
    fn max_min_all_neg_inf_segment_survives() {
        // A segment whose only value is -inf must report -inf, not 0
        // (the old clamp confused it with an empty segment).
        let data = [f32::NEG_INFINITY];
        let seg = [0];
        assert_eq!(segment_max(&data, &seg, 2, 1), vec![f32::NEG_INFINITY, 0.0]);
        let data = [f32::INFINITY];
        assert_eq!(segment_min(&data, &seg, 2, 1), vec![f32::INFINITY, 0.0]);
    }

    #[test]
    fn max_min_propagate_nan() {
        let data = [1.0, f32::NAN, 3.0, 4.0];
        let seg = [0, 0, 0, 1];
        let mx = segment_max(&data, &seg, 2, 1);
        assert!(mx[0].is_nan(), "NaN input poisons its segment: {mx:?}");
        assert_eq!(mx[1], 4.0);
        let mn = segment_min(&data, &seg, 2, 1);
        assert!(mn[0].is_nan());
        assert_eq!(mn[1], 4.0);
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let data = [1000.0, 1001.0];
        let seg = [0, 0];
        let w = segment_softmax_values(&data, &seg, 1, 1);
        assert!(w.iter().all(|x| x.is_finite()));
        assert!((w[0] + w[1] - 1.0).abs() < 1e-6);
        assert!(w[1] > w[0]);
    }

    #[test]
    fn prop_sum_equals_scalar_loop() {
        check("segment_sum matches naive", 60, |rng| {
            let n = rng.uniform(50);
            let k = 1 + rng.uniform(8);
            let d = 1 + rng.uniform(3);
            let data: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let seg: Vec<u32> = (0..n).map(|_| rng.uniform(k) as u32).collect();
            let fast = segment_sum(&data, &seg, k, d);
            let mut naive = vec![0.0f32; k * d];
            for i in 0..n {
                for j in 0..d {
                    naive[seg[i] as usize * d + j] += data[i * d + j];
                }
            }
            for (a, b) in fast.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn prop_mean_times_count_is_sum() {
        check("mean × count = sum", 40, |rng| {
            let n = 1 + rng.uniform(40);
            let k = 1 + rng.uniform(6);
            let data: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let seg: Vec<u32> = (0..n).map(|_| rng.uniform(k) as u32).collect();
            let mut counts = vec![0u32; k];
            for &s in &seg {
                counts[s as usize] += 1;
            }
            let sum = segment_sum(&data, &seg, k, 1);
            let mean = segment_mean(&data, &seg, k, 1);
            for s in 0..k {
                assert!((mean[s] * counts[s] as f32 - sum[s]).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn prop_softmax_rows_sum_to_one() {
        check("softmax sums to 1 per non-empty segment", 40, |rng| {
            let n = 1 + rng.uniform(40);
            let k = 1 + rng.uniform(6);
            let data: Vec<f32> = (0..n).map(|_| rng.range_f32(-5.0, 5.0)).collect();
            let seg: Vec<u32> = (0..n).map(|_| rng.uniform(k) as u32).collect();
            let w = segment_softmax_values(&data, &seg, k, 1);
            let sums = segment_sum(&w, &seg, k, 1);
            let mut counts = vec![0u32; k];
            for &s in &seg {
                counts[s as usize] += 1;
            }
            for s in 0..k {
                if counts[s] > 0 {
                    assert!((sums[s] - 1.0).abs() < 1e-5, "segment {s}: {}", sums[s]);
                }
            }
        });
    }

    #[test]
    fn prop_max_ge_mean_ge_min() {
        check("max ≥ mean ≥ min per segment", 40, |rng| {
            let n = 1 + rng.uniform(40);
            let k = 1 + rng.uniform(6);
            let data: Vec<f32> = (0..n).map(|_| rng.range_f32(-3.0, 3.0)).collect();
            let seg: Vec<u32> = (0..n).map(|_| rng.uniform(k) as u32).collect();
            let mx = segment_max(&data, &seg, k, 1);
            let mn = segment_min(&data, &seg, k, 1);
            let me = segment_mean(&data, &seg, k, 1);
            let mut counts = vec![0u32; k];
            for &s in &seg {
                counts[s as usize] += 1;
            }
            for s in 0..k {
                if counts[s] > 0 {
                    assert!(mx[s] >= me[s] - 1e-5);
                    assert!(me[s] >= mn[s] - 1e-5);
                }
            }
        });
    }
}
