//! Pure-Rust reference forward pass for the `mpnn` architecture.
//!
//! This mirrors `python/compile/model.py::forward` (arch `mpnn`,
//! deterministic mode) operation-for-operation on the CPU, consuming
//! the same padded batch and the same checkpoint parameters. The
//! integration test `aot_forward_matches_rust_reference` asserts the
//! AOT logits and these logits agree to float tolerance — the strongest
//! cross-language correctness check in the repo: it validates the whole
//! chain (Pallas kernel → jax model → HLO text → PJRT execution →
//! literal marshalling) against an independent implementation.

use std::collections::BTreeMap;

use crate::graph::pad::Padded;
use crate::runtime::batch::{root_indices, RootTask};
use crate::runtime::manifest::Manifest;
use crate::runtime::HostTensor;
use crate::{Error, Result};

/// Dense row-major matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// self @ w (w: [self.cols, n])
    pub fn matmul(&self, w: &Mat) -> Mat {
        assert_eq!(self.cols, w.rows);
        let mut out = Mat::zeros(self.rows, w.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let wrow = &w.data[k * w.cols..(k + 1) * w.cols];
                let orow = &mut out.data[i * w.cols..(i + 1) * w.cols];
                for (o, &b) in orow.iter_mut().zip(wrow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn add_bias(&mut self, b: &[f32]) {
        assert_eq!(self.cols, b.len());
        for r in 0..self.rows {
            for (v, &bb) in self.data[r * self.cols..(r + 1) * self.cols].iter_mut().zip(b) {
                *v += bb;
            }
        }
    }

    pub fn relu(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Per-row layer norm with scale/bias (eps 1e-5, matching L2).
    pub fn layer_norm(&mut self, scale: &[f32], bias: &[f32]) {
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            let mu = row.iter().sum::<f32>() / row.len() as f32;
            let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / row.len() as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (i, v) in row.iter_mut().enumerate() {
                *v = (*v - mu) * inv * scale[i] + bias[i];
            }
        }
    }

    /// Concatenate columns of several matrices (same row count).
    pub fn concat_cols(parts: &[&Mat]) -> Mat {
        let rows = parts[0].rows;
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            let mut at = 0;
            for p in parts {
                out.data[r * cols + at..r * cols + at + p.cols].copy_from_slice(p.row(r));
                at += p.cols;
            }
        }
        out
    }

    /// Gather rows by index.
    pub fn gather(&self, idx: &[i32]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.data[r * self.cols..(r + 1) * self.cols].copy_from_slice(self.row(i as usize));
        }
        out
    }

    /// Scatter-add rows into `n` segments.
    pub fn segment_sum(&self, seg: &[i32], n: usize) -> Mat {
        let mut out = Mat::zeros(n, self.cols);
        for (r, &s) in seg.iter().enumerate() {
            let dst = &mut out.data[s as usize * self.cols..(s as usize + 1) * self.cols];
            for (o, &v) in dst.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }
}

/// The unfused message-passing step, kept as the bit-for-bit oracle
/// for [`edge_conv_fused`]: gather both endpoint states, concat,
/// message MLP `relu(W·[s‖r] + b)`, then sum-pool to the receiver.
/// Materializes four `[num_edges, …]` matrices.
pub fn edge_conv_unfused(
    sender_h: &Mat,
    receiver_h: &Mat,
    sender_idx: &[i32],
    receiver_idx: &[i32],
    w: &Mat,
    b: &[f32],
    n_recv: usize,
) -> Mat {
    let sender = sender_h.gather(sender_idx);
    let receiver = receiver_h.gather(receiver_idx);
    let x = Mat::concat_cols(&[&sender, &receiver]);
    let mut msg = x.matmul(w);
    msg.add_bias(b);
    msg.relu();
    msg.segment_sum(receiver_idx, n_recv)
}

/// Fused edge convolution: one pass over the edges computing each
/// message row on an O(hidden)-sized scratch buffer and accumulating
/// straight into the receiver's output row — no `[num_edges, …]`
/// intermediates (the unfused path materializes gathered sender,
/// gathered receiver, their concat, and the messages).
///
/// Bit-for-bit equal to [`edge_conv_unfused`]: the per-row dot-product
/// loop mirrors [`Mat::matmul`] (including its skip of zero
/// activations), and edges are visited in ascending id order, which is
/// the accumulation order of [`Mat::segment_sum`].
pub fn edge_conv_fused(
    sender_h: &Mat,
    receiver_h: &Mat,
    sender_idx: &[i32],
    receiver_idx: &[i32],
    w: &Mat,
    b: &[f32],
    n_recv: usize,
) -> Mat {
    let in_cols = sender_h.cols + receiver_h.cols;
    assert_eq!(in_cols, w.rows, "edge_conv_fused: W shape");
    assert_eq!(w.cols, b.len(), "edge_conv_fused: bias shape");
    assert_eq!(sender_idx.len(), receiver_idx.len());
    let mut out = Mat::zeros(n_recv, w.cols);
    let mut xrow = vec![0.0f32; in_cols];
    let mut msg = vec![0.0f32; w.cols];
    for (&s, &r) in sender_idx.iter().zip(receiver_idx) {
        xrow[..sender_h.cols].copy_from_slice(sender_h.row(s as usize));
        xrow[sender_h.cols..].copy_from_slice(receiver_h.row(r as usize));
        // msg = xrow @ W, with matmul's zero-activation skip; the bias
        // is added *after* the dot products (float addition is not
        // associative — starting from `b` would change the bits).
        msg.fill(0.0);
        for (k, &a) in xrow.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let wrow = &w.data[k * w.cols..(k + 1) * w.cols];
            for (o, &wv) in msg.iter_mut().zip(wrow) {
                *o += a * wv;
            }
        }
        let dst = &mut out.data[r as usize * w.cols..(r as usize + 1) * w.cols];
        for ((o, &m), &bb) in dst.iter_mut().zip(&msg).zip(b) {
            let m = m + bb;
            *o += if m < 0.0 { 0.0 } else { m };
        }
    }
    out
}

/// Named parameter lookup over a checkpoint/params list.
pub struct ParamMap<'a>(BTreeMap<&'a str, &'a HostTensor>);

impl<'a> ParamMap<'a> {
    pub fn new(params: &'a [(String, HostTensor)]) -> ParamMap<'a> {
        ParamMap(params.iter().map(|(n, t)| (n.trim_start_matches("param."), t)).collect())
    }

    fn mat(&self, name: &str) -> Result<Mat> {
        let t = self
            .0
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("reference model: missing param {name:?}")))?;
        let (shape, data) = match t {
            HostTensor::F32(s, d) => (s, d),
            _ => return Err(Error::Runtime(format!("param {name:?} not f32"))),
        };
        match shape.len() {
            2 => Ok(Mat { rows: shape[0], cols: shape[1], data: data.clone() }),
            1 => Ok(Mat { rows: 1, cols: shape[0], data: data.clone() }),
            _ => Err(Error::Runtime(format!("param {name:?} has rank {}", shape.len()))),
        }
    }

    fn vec(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.mat(name)?.data)
    }
}

/// Model dims read from the manifest config.
struct RefConfig {
    hidden: usize,
    layers: usize,
    updates: BTreeMap<String, Vec<String>>,
    edge_endpoints: BTreeMap<String, (String, String)>,
    node_order: Vec<String>,
    id_embedding: BTreeMap<String, bool>,
    features: BTreeMap<String, Vec<String>>,
    num_classes: usize,
}

fn ref_config(manifest: &Manifest) -> Result<RefConfig> {
    let cfg = &manifest.config;
    let model = cfg.get("model")?;
    let mut updates = BTreeMap::new();
    for (k, v) in model.get("updates")?.as_obj()? {
        updates.insert(
            k.clone(),
            v.as_arr()?.iter().map(|s| Ok(s.as_str()?.to_string())).collect::<Result<Vec<_>>>()?,
        );
    }
    let schema = cfg.get("schema")?;
    let mut edge_endpoints = BTreeMap::new();
    for (k, v) in schema.get("edge_sets")?.as_obj()? {
        let arr = v.as_arr()?;
        edge_endpoints.insert(
            k.clone(),
            (arr[0].as_str()?.to_string(), arr[1].as_str()?.to_string()),
        );
    }
    let mut node_order = Vec::new();
    let mut id_embedding = BTreeMap::new();
    let mut features = BTreeMap::new();
    for (k, v) in schema.get("node_sets")?.as_obj()? {
        node_order.push(k.clone());
        id_embedding.insert(
            k.clone(),
            v.opt("id_embedding").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false),
        );
        let mut fs = Vec::new();
        if let Some(f) = v.opt("features") {
            for name in f.as_obj()?.keys() {
                fs.push(name.clone());
            }
        }
        features.insert(k.clone(), fs);
    }
    Ok(RefConfig {
        hidden: manifest.model("mpnn")?.hidden_dim,
        layers: manifest.model("mpnn")?.num_layers,
        updates,
        edge_endpoints,
        node_order,
        id_embedding,
        features,
        num_classes: cfg.get("train")?.get("num_classes")?.as_usize()?,
    })
}

/// Compute logits `[num_roots, num_classes]` exactly like the AOT
/// `forward` program (arch mpnn, eval mode).
pub fn mpnn_forward_reference(
    manifest: &Manifest,
    params: &[(String, HostTensor)],
    padded: &Padded,
    task: &RootTask,
) -> Result<Mat> {
    let rc = ref_config(manifest)?;
    let p = ParamMap::new(params);
    let g = &padded.graph;

    // Initial states (MapFeatures).
    let mut h: BTreeMap<String, Mat> = BTreeMap::new();
    for set in &rc.node_order {
        let n = g.num_nodes(set)?;
        let feats = &rc.features[set];
        if !feats.is_empty() {
            let mut state = Mat::zeros(n, rc.hidden);
            for fname in feats {
                let (dims, data) = g.node_set(set)?.feature(fname)?.as_f32()?;
                let x = Mat { rows: n, cols: dims[0], data: data.to_vec() };
                let xw = x.matmul(&p.mat(&format!("enc.{set}.{fname}.w"))?);
                for (o, v) in state.data.iter_mut().zip(&xw.data) {
                    *o += v;
                }
            }
            let first = &feats[0];
            state.add_bias(&p.vec(&format!("enc.{set}.{first}.b"))?);
            state.relu();
            h.insert(set.clone(), state);
        } else if rc.id_embedding[set] {
            let (_, ids) = g.node_set(set)?.feature("#id")?.as_i64()?;
            let table = p.mat(&format!("emb.{set}"))?;
            let idx: Vec<i32> = ids.iter().map(|&i| i as i32).collect();
            h.insert(set.clone(), table.gather(&idx));
        } else {
            h.insert(set.clone(), Mat::zeros(n, rc.hidden));
        }
    }

    // GraphUpdate rounds (receiver = SOURCE; messages relu(W[s||r]+b)).
    for layer in 0..rc.layers {
        let mut new_h = h.clone();
        for (node_set, edge_list) in &rc.updates {
            let n_recv = g.num_nodes(node_set)?;
            let mut pooled = Vec::new();
            let mut edge_names: Vec<&String> = edge_list.iter().collect();
            edge_names.sort();
            for es in edge_names {
                let adj = &g.edge_set(es)?.adjacency;
                let src: Vec<i32> = adj.source.iter().map(|&x| x as i32).collect();
                let tgt: Vec<i32> = adj.target.iter().map(|&x| x as i32).collect();
                let send_set = &rc.edge_endpoints[es].1;
                // Fused gather→concat→MLP→pool; bit-for-bit equal to
                // the unfused sequence (edge_conv_unfused) but without
                // the four [num_edges, …] intermediates.
                pooled.push(edge_conv_fused(
                    &h[send_set],
                    &h[node_set],
                    &tgt,
                    &src,
                    &p.mat(&format!("l{layer}.{node_set}.{es}.msg.w"))?,
                    &p.vec(&format!("l{layer}.{node_set}.{es}.msg.b"))?,
                    n_recv,
                ));
            }
            let mut parts: Vec<&Mat> = vec![&h[node_set]];
            parts.extend(pooled.iter());
            let x = Mat::concat_cols(&parts);
            let mut next = x.matmul(&p.mat(&format!("l{layer}.{node_set}.next.w"))?);
            next.add_bias(&p.vec(&format!("l{layer}.{node_set}.next.b"))?);
            next.relu();
            // layer norm (the mag config enables it)
            if params.iter().any(|(n, _)| n == &format!("param.l{layer}.{node_set}.ln.scale")) {
                next.layer_norm(
                    &p.vec(&format!("l{layer}.{node_set}.ln.scale"))?,
                    &p.vec(&format!("l{layer}.{node_set}.ln.bias"))?,
                );
            }
            new_h.insert(node_set.clone(), next);
        }
        h = new_h;
    }

    // Root readout.
    let num_roots = manifest.pad_spec()?.component_cap - 1;
    let roots = root_indices(padded, &task.root_set, num_roots)?;
    let root_states = h[&task.root_set].gather(&roots);
    let mut logits = root_states.matmul(&p.mat("head.w")?);
    logits.add_bias(&p.vec("head.b")?);
    debug_assert_eq!(logits.cols, rc.num_classes);
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_ops() {
        let a = Mat { rows: 2, cols: 3, data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let w = Mat { rows: 3, cols: 2, data: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0] };
        let c = a.matmul(&w);
        assert_eq!(c.data, vec![4.0, 5.0, 10.0, 11.0]);
        let g = a.gather(&[1, 0, 1]);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(0), &[4.0, 5.0, 6.0]);
        let s = a.segment_sum(&[0, 0], 2);
        assert_eq!(s.row(0), &[5.0, 7.0, 9.0]);
        assert_eq!(s.row(1), &[0.0, 0.0, 0.0]);
        let cc = Mat::concat_cols(&[&a, &a]);
        assert_eq!(cc.cols, 6);
        assert_eq!(cc.row(1), &[4.0, 5.0, 6.0, 4.0, 5.0, 6.0]);
    }

    /// The fused edge conv must reproduce the unfused oracle exactly —
    /// this is what keeps `mpnn_forward_reference` a valid bit-level
    /// reference for the AOT programs after the fusion.
    #[test]
    fn fused_edge_conv_matches_unfused_bitexact() {
        use crate::util::proptest::check;
        check("edge_conv fused == unfused", 40, |rng| {
            let n_send = 1 + rng.uniform(12);
            let n_recv = 1 + rng.uniform(12);
            let n_edges = rng.uniform(40);
            let d_in = 1 + rng.uniform(6);
            let d_out = 1 + rng.uniform(6);
            let mk = |rows: usize, cols: usize, rng: &mut crate::util::rng::Rng| Mat {
                rows,
                cols,
                data: (0..rows * cols)
                    .map(|_| {
                        // Mix in exact zeros to exercise matmul's
                        // zero-activation skip on both paths.
                        if rng.chance(0.2) {
                            0.0
                        } else {
                            rng.range_f32(-2.0, 2.0)
                        }
                    })
                    .collect(),
            };
            let sender_h = mk(n_send, d_in, rng);
            let receiver_h = mk(n_recv, d_in, rng);
            let w = mk(2 * d_in, d_out, rng);
            let b: Vec<f32> = (0..d_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let sidx: Vec<i32> = (0..n_edges).map(|_| rng.uniform(n_send) as i32).collect();
            let ridx: Vec<i32> = (0..n_edges).map(|_| rng.uniform(n_recv) as i32).collect();
            let want = edge_conv_unfused(&sender_h, &receiver_h, &sidx, &ridx, &w, &b, n_recv);
            let got = edge_conv_fused(&sender_h, &receiver_h, &sidx, &ridx, &w, &b, n_recv);
            assert_eq!(want.rows, got.rows);
            assert_eq!(want.cols, got.cols);
            for (i, (x, y)) in want.data.iter().zip(&got.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
            }
        });
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut m = Mat { rows: 1, cols: 4, data: vec![1.0, 2.0, 3.0, 4.0] };
        m.layer_norm(&[1.0; 4], &[0.0; 4]);
        let mu: f32 = m.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        let var: f32 = m.data.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-4);
    }
}
