//! Pure-Rust reference forward pass for the `mpnn` architecture.
//!
//! This mirrors `python/compile/model.py::forward` (arch `mpnn`,
//! deterministic mode) operation-for-operation on the CPU, consuming
//! the same padded batch and the same checkpoint parameters. The
//! integration test `aot_forward_matches_rust_reference` asserts the
//! AOT logits and these logits agree to float tolerance — the strongest
//! cross-language correctness check in the repo: it validates the whole
//! chain (Pallas kernel → jax model → HLO text → PJRT execution →
//! literal marshalling) against an independent implementation.
//!
//! The forward is exposed as **staged functions** ([`encode_dense`],
//! [`edge_conv_tape`], [`node_update`], [`root_readout`]) rather than
//! one monolithic pass: each stage returns its pre-activation(s)
//! alongside the output, which is exactly what the native training
//! engine ([`crate::train::native`]) records on its tape for the
//! backward pass. [`mpnn_forward_reference`] composes the same stages
//! (with the fused edge convolution on the hot edge loop), so the
//! reference and the native engine share one source of truth for the
//! forward semantics.

use std::collections::BTreeMap;

use crate::analysis::diag::{codes, Diagnostic};
use crate::graph::pad::Padded;
use crate::runtime::batch::{root_indices, RootTask};
use crate::runtime::manifest::Manifest;
use crate::runtime::HostTensor;
use crate::util::json::Json;
use crate::{Error, Result};

/// Dense row-major matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// self @ w (w: [self.cols, n])
    pub fn matmul(&self, w: &Mat) -> Mat {
        assert_eq!(self.cols, w.rows);
        let mut out = Mat::zeros(self.rows, w.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let wrow = &w.data[k * w.cols..(k + 1) * w.cols];
                let orow = &mut out.data[i * w.cols..(i + 1) * w.cols];
                for (o, &b) in orow.iter_mut().zip(wrow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn add_bias(&mut self, b: &[f32]) {
        assert_eq!(self.cols, b.len());
        for r in 0..self.rows {
            for (v, &bb) in self.data[r * self.cols..(r + 1) * self.cols].iter_mut().zip(b) {
                *v += bb;
            }
        }
    }

    pub fn relu(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Per-row layer norm with scale/bias (eps 1e-5, matching L2).
    pub fn layer_norm(&mut self, scale: &[f32], bias: &[f32]) {
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            let mu = row.iter().sum::<f32>() / row.len() as f32;
            let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / row.len() as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (i, v) in row.iter_mut().enumerate() {
                *v = (*v - mu) * inv * scale[i] + bias[i];
            }
        }
    }

    /// Concatenate columns of several matrices (same row count).
    pub fn concat_cols(parts: &[&Mat]) -> Mat {
        let rows = parts[0].rows;
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            let mut at = 0;
            for p in parts {
                out.data[r * cols + at..r * cols + at + p.cols].copy_from_slice(p.row(r));
                at += p.cols;
            }
        }
        out
    }

    /// Gather rows by index.
    pub fn gather(&self, idx: &[i32]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.data[r * self.cols..(r + 1) * self.cols].copy_from_slice(self.row(i as usize));
        }
        out
    }

    /// Scatter-add rows into `n` segments.
    pub fn segment_sum(&self, seg: &[i32], n: usize) -> Mat {
        let mut out = Mat::zeros(n, self.cols);
        for (r, &s) in seg.iter().enumerate() {
            let dst = &mut out.data[s as usize * self.cols..(s as usize + 1) * self.cols];
            for (o, &v) in dst.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Transposed copy (used by the reverse-mode matmul rules).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Element-wise `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.rows, other.rows, "add_assign: row mismatch");
        assert_eq!(self.cols, other.cols, "add_assign: col mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Element-wise scale by a scalar.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Per-column sums (the bias gradient).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// A zero matrix of the same shape.
    pub fn zeros_like(&self) -> Mat {
        Mat::zeros(self.rows, self.cols)
    }
}

/// The unfused message-passing step, kept as the bit-for-bit oracle
/// for [`edge_conv_fused`]: gather both endpoint states, concat,
/// message MLP `relu(W·[s‖r] + b)`, then sum-pool to the receiver.
/// Materializes four `[num_edges, …]` matrices.
pub fn edge_conv_unfused(
    sender_h: &Mat,
    receiver_h: &Mat,
    sender_idx: &[i32],
    receiver_idx: &[i32],
    w: &Mat,
    b: &[f32],
    n_recv: usize,
) -> Mat {
    let sender = sender_h.gather(sender_idx);
    let receiver = receiver_h.gather(receiver_idx);
    let x = Mat::concat_cols(&[&sender, &receiver]);
    let mut msg = x.matmul(w);
    msg.add_bias(b);
    msg.relu();
    msg.segment_sum(receiver_idx, n_recv)
}

/// Saved activations of one edge convolution — the tape entries the
/// native backward pass needs: the concatenated per-edge input and the
/// pre-relu messages.
#[derive(Debug, Clone)]
pub struct EdgeConvSaved {
    /// `[num_edges, d_sender + d_receiver]` gathered+concatenated input.
    pub x_edge: Mat,
    /// `[num_edges, d_out]` messages before the relu.
    pub z_msg: Mat,
}

/// Tape variant of the edge convolution: the same staged sequence as
/// [`edge_conv_unfused`] (and therefore bit-for-bit equal to
/// [`edge_conv_fused`] — see the fusion property test), returning the
/// saved activations alongside the pooled output.
pub fn edge_conv_tape(
    sender_h: &Mat,
    receiver_h: &Mat,
    sender_idx: &[i32],
    receiver_idx: &[i32],
    w: &Mat,
    b: &[f32],
    n_recv: usize,
) -> (Mat, EdgeConvSaved) {
    let sender = sender_h.gather(sender_idx);
    let receiver = receiver_h.gather(receiver_idx);
    let x_edge = Mat::concat_cols(&[&sender, &receiver]);
    let mut z_msg = x_edge.matmul(w);
    z_msg.add_bias(b);
    let mut msg = z_msg.clone();
    msg.relu();
    let pooled = msg.segment_sum(receiver_idx, n_recv);
    (pooled, EdgeConvSaved { x_edge, z_msg })
}

/// Fused edge convolution: one pass over the edges computing each
/// message row on an O(hidden)-sized scratch buffer and accumulating
/// straight into the receiver's output row — no `[num_edges, …]`
/// intermediates (the unfused path materializes gathered sender,
/// gathered receiver, their concat, and the messages).
///
/// Bit-for-bit equal to [`edge_conv_unfused`]: the per-row dot-product
/// loop mirrors [`Mat::matmul`] (including its skip of zero
/// activations), and edges are visited in ascending id order, which is
/// the accumulation order of [`Mat::segment_sum`].
pub fn edge_conv_fused(
    sender_h: &Mat,
    receiver_h: &Mat,
    sender_idx: &[i32],
    receiver_idx: &[i32],
    w: &Mat,
    b: &[f32],
    n_recv: usize,
) -> Mat {
    let in_cols = sender_h.cols + receiver_h.cols;
    assert_eq!(in_cols, w.rows, "edge_conv_fused: W shape");
    assert_eq!(w.cols, b.len(), "edge_conv_fused: bias shape");
    assert_eq!(sender_idx.len(), receiver_idx.len());
    let mut out = Mat::zeros(n_recv, w.cols);
    let mut xrow = vec![0.0f32; in_cols];
    let mut msg = vec![0.0f32; w.cols];
    for (&s, &r) in sender_idx.iter().zip(receiver_idx) {
        xrow[..sender_h.cols].copy_from_slice(sender_h.row(s as usize));
        xrow[sender_h.cols..].copy_from_slice(receiver_h.row(r as usize));
        // msg = xrow @ W, with matmul's zero-activation skip; the bias
        // is added *after* the dot products (float addition is not
        // associative — starting from `b` would change the bits).
        msg.fill(0.0);
        for (k, &a) in xrow.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let wrow = &w.data[k * w.cols..(k + 1) * w.cols];
            for (o, &wv) in msg.iter_mut().zip(wrow) {
                *o += a * wv;
            }
        }
        let dst = &mut out.data[r as usize * w.cols..(r as usize + 1) * w.cols];
        for ((o, &m), &bb) in dst.iter_mut().zip(&msg).zip(b) {
            let m = m + bb;
            *o += if m < 0.0 { 0.0 } else { m };
        }
    }
    out
}

/// Stage: initial node state from dense features —
/// `z = Σ_f x_f @ W_f + b`, `h = relu(z)`. Returns `(h, z)`; the
/// pre-activation `z` is the tape entry the backward pass masks the
/// relu with.
pub fn encode_dense(xs: &[Mat], ws: &[&Mat], b: &[f32]) -> (Mat, Mat) {
    assert_eq!(xs.len(), ws.len(), "encode_dense: feature/weight count");
    assert!(!xs.is_empty(), "encode_dense: no features");
    let mut z = Mat::zeros(xs[0].rows, ws[0].cols);
    for (x, w) in xs.iter().zip(ws) {
        let xw = x.matmul(w);
        z.add_assign(&xw);
    }
    z.add_bias(b);
    let mut h = z.clone();
    h.relu();
    (h, z)
}

/// Saved activations of one next-state update: the concatenated input
/// `[h ‖ pooled…]` and the pre-relu output.
#[derive(Debug, Clone)]
pub struct NodeUpdateSaved {
    pub x_cat: Mat,
    pub z: Mat,
}

/// Stage: next-state MLP — `x = concat(parts)`, `z = x @ W + b`,
/// `h = relu(z)`. Returns `(h, saved)`.
pub fn node_update(parts: &[&Mat], w: &Mat, b: &[f32]) -> (Mat, NodeUpdateSaved) {
    let x_cat = Mat::concat_cols(parts);
    let mut z = x_cat.matmul(w);
    z.add_bias(b);
    let mut h = z.clone();
    h.relu();
    (h, NodeUpdateSaved { x_cat, z })
}

/// Stage: root readout — gather the root rows, apply the linear head.
/// Returns `(logits, root_states)`; the gathered states are the tape
/// entry for the head's weight gradient.
pub fn root_readout(h_root: &Mat, roots: &[i32], w: &Mat, b: &[f32]) -> (Mat, Mat) {
    let root_states = h_root.gather(roots);
    let mut logits = root_states.matmul(w);
    logits.add_bias(b);
    (logits, root_states)
}

/// Named parameter lookup over a checkpoint/params list.
pub struct ParamMap<'a>(BTreeMap<&'a str, &'a HostTensor>);

impl<'a> ParamMap<'a> {
    pub fn new(params: &'a [(String, HostTensor)]) -> ParamMap<'a> {
        ParamMap(params.iter().map(|(n, t)| (n.trim_start_matches("param."), t)).collect())
    }

    fn mat(&self, name: &str) -> Result<Mat> {
        let t = self
            .0
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("reference model: missing param {name:?}")))?;
        let (shape, data) = match t {
            HostTensor::F32(s, d) => (s, d),
            _ => return Err(Error::Runtime(format!("param {name:?} not f32"))),
        };
        match shape.len() {
            2 => Ok(Mat { rows: shape[0], cols: shape[1], data: data.clone() }),
            1 => Ok(Mat { rows: 1, cols: shape[0], data: data.clone() }),
            _ => Err(Error::Runtime(format!("param {name:?} has rank {}", shape.len()))),
        }
    }

    fn vec(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.mat(name)?.data)
    }
}

/// The training objective read off a config's `task` block — which
/// readout head sits on the shared GNN trunk, with its loss and
/// negative-sampling knobs. Parsed and validated here (the config
/// funnel every entry point shares — see
/// [`crate::layers::ModelBuilder`]); the executable head lives in
/// [`crate::tasks`].
#[derive(Debug, Clone, PartialEq)]
pub struct TaskConfig {
    /// `task.type`: `"root_classification"` (the default) |
    /// `"link_prediction"` | `"graph_regression"`.
    pub kind: String,
    /// Node set carrying roots / readout states (default `"paper"`).
    pub root_set: String,
    /// Root label feature for classification (default `"labels"`).
    pub label_feature: String,
    /// Edge set whose held-out edges are the link-prediction positives
    /// (default `"cites"`; must be homogeneous).
    pub edge_set: String,
    /// Pair scorer: `"dot"` (parameter-free) | `"hadamard"` (MLP over
    /// the element-wise product).
    pub readout: String,
    /// Link loss: `"softmax"` (1 positive vs K negatives cross-entropy)
    /// | `"margin"` (pairwise hinge).
    pub loss: String,
    /// Hinge margin for `loss == "margin"`.
    pub margin: f32,
    /// Negatives per positive pair (seeded-uniform, co-sampled into the
    /// pair subgraph so their final states exist).
    pub negatives: usize,
    /// The k of hits@k.
    pub hits_k: usize,
    /// Fraction of `edge_set` held out of the message-passing graph as
    /// supervision pairs.
    pub holdout_fraction: f64,
    /// Seed for the edge-holdout split and negative sampling.
    pub split_seed: u64,
    /// Hadamard-MLP hidden width (0 = `message_dim`).
    pub mlp_dim: usize,
    /// Regression target feature on the root node (default `"year"`).
    pub target_feature: String,
    /// Regression target normalization: `t_norm = (t - shift) * scale`.
    pub target_shift: f32,
    pub target_scale: f32,
}

impl Default for TaskConfig {
    fn default() -> TaskConfig {
        TaskConfig {
            kind: "root_classification".into(),
            root_set: "paper".into(),
            label_feature: "labels".into(),
            edge_set: "cites".into(),
            readout: "dot".into(),
            loss: "softmax".into(),
            margin: 1.0,
            negatives: 4,
            hits_k: 3,
            holdout_fraction: 0.1,
            split_seed: 0x11bd,
            mlp_dim: 0,
            target_feature: "year".into(),
            target_shift: 0.0,
            target_scale: 1.0,
        }
    }
}

/// Keys a config's `model` block may carry. The AOT/python side owns
/// several of them (`num_heads`, `use_pallas_*`, …); listing them here
/// keeps one funnel that accepts both engines' configs while rejecting
/// typos (`att_dims`) as structured errors instead of silently falling
/// back to defaults.
const MODEL_KEYS: &[&str] = &[
    "type",
    "arch",
    "hidden_dim",
    "hidden_dim_override",
    "message_dim",
    "num_layers",
    "att_dim",
    "sage_reduce",
    "updates",
    "num_heads",
    "dropout",
    "use_layer_norm",
    "use_pallas_messages",
    "use_pallas_segment",
    "reduce_type",
];

/// Keys a config's `task` block may carry (see [`TaskConfig`]).
const TASK_KEYS: &[&str] = &[
    "type",
    "root_set",
    "label_feature",
    "edge_set",
    "readout",
    "loss",
    "margin",
    "negatives",
    "hits_k",
    "holdout_fraction",
    "split_seed",
    "mlp_dim",
    "target_feature",
    "target_shift",
    "target_scale",
];

/// Reject unknown keys in a config block (typos like `att_dims` must
/// not silently fall back to defaults).
fn reject_unknown_keys(block: &Json, allowed: &[&str], name: &str) -> Result<()> {
    for key in block.as_obj()?.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(Diagnostic::error(
                codes::UNKNOWN_KEY,
                format!("$.{name}.{key}"),
                format!("{name} block has unknown key {key:?} — known keys: {allowed:?}"),
            )
            .into_error());
        }
    }
    Ok(())
}

impl TaskConfig {
    /// Parse and validate a config's optional `task` block; absent
    /// means root classification with the defaults.
    pub fn from_config(cfg: &Json) -> Result<TaskConfig> {
        let Some(t) = cfg.opt("task") else {
            return Ok(TaskConfig::default());
        };
        reject_unknown_keys(t, TASK_KEYS, "task")?;
        let mut out = TaskConfig::default();
        if let Some(v) = t.opt("type") {
            out.kind = v.as_str()?.to_string();
        }
        match out.kind.as_str() {
            "root_classification" | "link_prediction" | "graph_regression" => {}
            other => {
                return Err(Diagnostic::error(
                    codes::UNKNOWN_ENUM,
                    "$.task.type",
                    format!(
                        "task.type {other:?} unknown (want \
                         root_classification|link_prediction|graph_regression)"
                    ),
                )
                .into_error());
            }
        }
        if let Some(v) = t.opt("root_set") {
            out.root_set = v.as_str()?.to_string();
        }
        if let Some(v) = t.opt("label_feature") {
            out.label_feature = v.as_str()?.to_string();
        }
        if let Some(v) = t.opt("edge_set") {
            out.edge_set = v.as_str()?.to_string();
        }
        if let Some(v) = t.opt("readout") {
            out.readout = v.as_str()?.to_string();
        }
        if !matches!(out.readout.as_str(), "dot" | "hadamard") {
            return Err(Diagnostic::error(
                codes::UNKNOWN_ENUM,
                "$.task.readout",
                format!("task.readout {:?} unknown (want dot|hadamard)", out.readout),
            )
            .into_error());
        }
        if let Some(v) = t.opt("loss") {
            out.loss = v.as_str()?.to_string();
        }
        if !matches!(out.loss.as_str(), "softmax" | "margin") {
            return Err(Diagnostic::error(
                codes::UNKNOWN_ENUM,
                "$.task.loss",
                format!("task.loss {:?} unknown (want softmax|margin)", out.loss),
            )
            .into_error());
        }
        if let Some(v) = t.opt("margin") {
            out.margin = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("negatives") {
            out.negatives = v.as_usize()?;
        }
        if let Some(v) = t.opt("hits_k") {
            out.hits_k = v.as_usize()?;
        }
        if let Some(v) = t.opt("holdout_fraction") {
            out.holdout_fraction = v.as_f64()?;
        }
        if let Some(v) = t.opt("split_seed") {
            out.split_seed = v.as_i64()? as u64;
        }
        if let Some(v) = t.opt("mlp_dim") {
            out.mlp_dim = v.as_usize()?;
        }
        if let Some(v) = t.opt("target_feature") {
            out.target_feature = v.as_str()?.to_string();
        }
        if let Some(v) = t.opt("target_shift") {
            out.target_shift = v.as_f64()? as f32;
        }
        if let Some(v) = t.opt("target_scale") {
            out.target_scale = v.as_f64()? as f32;
        }
        if out.kind == "link_prediction" {
            if out.negatives == 0 {
                return Err(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.negatives",
                    "task.negatives is 0 — link prediction needs at least one \
                     negative per positive pair",
                )
                .into_error());
            }
            if out.hits_k == 0 {
                return Err(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.hits_k",
                    "task.hits_k is 0 (want ≥ 1)",
                )
                .into_error());
            }
            if !(out.holdout_fraction > 0.0 && out.holdout_fraction < 1.0) {
                return Err(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.holdout_fraction",
                    format!(
                        "task.holdout_fraction {} outside (0, 1)",
                        out.holdout_fraction
                    ),
                )
                .into_error());
            }
            if out.margin <= 0.0 && out.loss == "margin" {
                return Err(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.margin",
                    format!("task.margin {} must be positive for the margin loss", out.margin),
                )
                .into_error());
            }
        }
        if out.kind == "graph_regression" && out.target_scale == 0.0 {
            return Err(Diagnostic::error(
                codes::BAD_TASK_KNOB,
                "$.task.target_scale",
                "task.target_scale is 0 — the regression target would collapse",
            )
            .into_error());
        }
        Ok(out)
    }
}

/// The mpnn architecture read off a config: dims, the per-node-set
/// update lists, the schema's endpoints and features. Shared between
/// the AOT reference forward and the native training engine (which
/// also needs `message`, `feature_dims` and `cardinality` to create
/// parameters from scratch).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Convolution architecture applied to every edge set —
    /// `"mpnn"` | `"gcn"` | `"sage"` | `"gatv2"`; parsed from the
    /// config's `model.type` (falling back to `model.arch`), validated
    /// by [`crate::layers::ModelBuilder`].
    pub arch: String,
    pub hidden: usize,
    /// Message MLP output width (== hidden for the shipped configs).
    pub message: usize,
    /// GATv2 attention hidden width (`model.att_dim`, default
    /// `message`).
    pub att_dim: usize,
    /// GraphSAGE neighbor reduction (`model.sage_reduce`):
    /// `"mean"` | `"max"`.
    pub sage_reduce: String,
    pub layers: usize,
    /// node set -> edge sets pooled into its update.
    pub updates: BTreeMap<String, Vec<String>>,
    /// edge set -> (source node set, target node set).
    pub edge_endpoints: BTreeMap<String, (String, String)>,
    /// All node sets, in deterministic (sorted) order.
    pub node_order: Vec<String>,
    /// node set -> uses an id-embedding table as its initial state.
    pub id_embedding: BTreeMap<String, bool>,
    /// node set -> dense feature names feeding its encoder (sorted).
    pub features: BTreeMap<String, Vec<String>>,
    /// node set -> feature name -> per-item dimension.
    pub feature_dims: BTreeMap<String, BTreeMap<String, usize>>,
    /// node set -> embedding-table cardinality (id-embedding sets).
    pub cardinality: BTreeMap<String, usize>,
    pub num_classes: usize,
    /// The training objective (config `task` block; defaults to root
    /// classification). Selects the readout head the native model is
    /// built with — see [`crate::tasks`].
    pub task: TaskConfig,
}

impl ModelConfig {
    /// Parse from a run config document (the `config` object of
    /// `artifacts/manifest.json`, or a raw `configs/*.json` file —
    /// both carry `model` / `schema` / `train`).
    pub fn from_config(cfg: &Json) -> Result<ModelConfig> {
        let model = cfg.get("model")?;
        reject_unknown_keys(model, MODEL_KEYS, "model")?;
        let task = TaskConfig::from_config(cfg)?;
        let mut updates = BTreeMap::new();
        for (k, v) in model.get("updates")?.as_obj()? {
            updates.insert(
                k.clone(),
                v.as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        let schema = cfg.get("schema")?;
        let mut edge_endpoints = BTreeMap::new();
        for (k, v) in schema.get("edge_sets")?.as_obj()? {
            let arr = v.as_arr()?;
            if arr.len() != 2 {
                return Err(Diagnostic::error(
                    codes::CONFIG,
                    format!("$.schema.edge_sets.{k}"),
                    format!("edge set {k:?}: want [source, target]"),
                )
                .into_error());
            }
            edge_endpoints.insert(
                k.clone(),
                (arr[0].as_str()?.to_string(), arr[1].as_str()?.to_string()),
            );
        }
        let mut node_order = Vec::new();
        let mut id_embedding = BTreeMap::new();
        let mut features = BTreeMap::new();
        let mut feature_dims = BTreeMap::new();
        let mut cardinality = BTreeMap::new();
        for (k, v) in schema.get("node_sets")?.as_obj()? {
            node_order.push(k.clone());
            id_embedding.insert(
                k.clone(),
                v.opt("id_embedding").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false),
            );
            let mut fs = Vec::new();
            let mut dims = BTreeMap::new();
            if let Some(f) = v.opt("features") {
                for (name, dim) in f.as_obj()? {
                    fs.push(name.clone());
                    dims.insert(name.clone(), dim.as_usize().unwrap_or(0));
                }
            }
            features.insert(k.clone(), fs);
            feature_dims.insert(k.clone(), dims);
            if let Some(c) = v.opt("cardinality") {
                cardinality.insert(k.clone(), c.as_usize()?);
            }
        }
        // `type` is the layer subsystem's key; `arch` the AOT/python
        // side's. The two vocabularies share only "mpnn" (the AOT
        // engine's gcn/sage/gatv2 are *different models* — other
        // normalization, activation and parameter layout), so a legacy
        // `arch` key alone may select nothing but mpnn: anything else
        // must opt into the native zoo explicitly via `type`. A config
        // carrying both keys with different values is a drift bug.
        let arch = match (model.opt("type"), model.opt("arch")) {
            (Some(t), Some(a)) if t.as_str()? != a.as_str()? => {
                return Err(Diagnostic::error(
                    codes::ARCH_CONFLICT,
                    "$.model.type",
                    format!(
                        "model.type {:?} and model.arch {:?} disagree — remove one",
                        t.as_str()?,
                        a.as_str()?
                    ),
                )
                .into_error());
            }
            (Some(v), _) => v.as_str()?.to_string(),
            (None, Some(v)) => {
                let a = v.as_str()?;
                if a != "mpnn" {
                    return Err(Diagnostic::error(
                        codes::ARCH_CONFLICT,
                        "$.model.arch",
                        format!(
                            "model.arch {a:?} names an AOT-engine architecture, which is \
                             not the same model as the native layer zoo's — select the \
                             native convolution explicitly via model.type \
                             (mpnn|gcn|sage|gatv2)"
                        ),
                    )
                    .into_error());
                }
                a.to_string()
            }
            (None, None) => "mpnn".to_string(),
        };
        let message = model.get("message_dim")?.as_usize()?;
        let att_dim = match model.opt("att_dim") {
            Some(v) => v.as_usize()?,
            None => message,
        };
        let sage_reduce = match model.opt("sage_reduce") {
            Some(v) => v.as_str()?.to_string(),
            None => "mean".to_string(),
        };
        Ok(ModelConfig {
            arch,
            hidden: model.get("hidden_dim")?.as_usize()?,
            message,
            att_dim,
            sage_reduce,
            layers: model.get("num_layers")?.as_usize()?,
            updates,
            edge_endpoints,
            node_order,
            id_embedding,
            features,
            feature_dims,
            cardinality,
            num_classes: cfg.get("train")?.get("num_classes")?.as_usize()?,
            task,
        })
    }

    /// Parse from an AOT manifest; the lowered model entry's dims win
    /// over the raw config when present.
    pub fn from_manifest(m: &Manifest) -> Result<ModelConfig> {
        let mut cfg = ModelConfig::from_config(&m.config)?;
        if let Ok(entry) = m.model("mpnn") {
            cfg.hidden = entry.hidden_dim;
            cfg.message = entry.message_dim;
            cfg.layers = entry.num_layers;
        }
        Ok(cfg)
    }

    /// The synth-MAG architecture (§8 schema) over a generator config —
    /// lets tests and benches build a model without a manifest.
    pub fn for_mag(
        mag: &crate::synth::mag::MagConfig,
        hidden: usize,
        message: usize,
        layers: usize,
    ) -> ModelConfig {
        let s = |x: &str| x.to_string();
        let mut updates = BTreeMap::new();
        updates.insert(s("paper"), vec![s("cites"), s("written"), s("has_topic")]);
        updates.insert(s("author"), vec![s("writes"), s("affiliated_with")]);
        let mut edge_endpoints = BTreeMap::new();
        edge_endpoints.insert(s("cites"), (s("paper"), s("paper")));
        edge_endpoints.insert(s("written"), (s("paper"), s("author")));
        edge_endpoints.insert(s("writes"), (s("author"), s("paper")));
        edge_endpoints.insert(s("affiliated_with"), (s("author"), s("institution")));
        edge_endpoints.insert(s("has_topic"), (s("paper"), s("field_of_study")));
        let node_order =
            vec![s("author"), s("field_of_study"), s("institution"), s("paper")];
        let mut id_embedding = BTreeMap::new();
        let mut features = BTreeMap::new();
        let mut feature_dims = BTreeMap::new();
        let mut cardinality = BTreeMap::new();
        for set in &node_order {
            id_embedding.insert(set.clone(), set == "institution" || set == "field_of_study");
            features.insert(set.clone(), Vec::new());
            feature_dims.insert(set.clone(), BTreeMap::new());
        }
        features.insert(s("paper"), vec![s("feat")]);
        feature_dims
            .entry(s("paper"))
            .or_default()
            .insert(s("feat"), mag.feature_dim);
        cardinality.insert(s("institution"), mag.num_institutions);
        cardinality.insert(s("field_of_study"), mag.num_fields);
        ModelConfig {
            arch: s("mpnn"),
            hidden,
            message,
            att_dim: message,
            sage_reduce: s("mean"),
            layers,
            updates,
            edge_endpoints,
            node_order,
            id_embedding,
            features,
            feature_dims,
            cardinality,
            num_classes: mag.num_classes,
            task: TaskConfig::default(),
        }
    }

    /// The same config with a different convolution architecture — the
    /// knob tests and benches use to walk the model zoo without
    /// re-deriving a whole config.
    pub fn with_arch(mut self, arch: &str) -> ModelConfig {
        self.arch = arch.to_string();
        self
    }

    /// The same config with a different task — the knob tests and
    /// benches use to walk the task zoo without re-deriving a config.
    pub fn with_task(mut self, task: TaskConfig) -> ModelConfig {
        self.task = task;
        self
    }
}

/// Compute logits `[num_roots, num_classes]` exactly like the AOT
/// `forward` program (arch mpnn, eval mode).
pub fn mpnn_forward_reference(
    manifest: &Manifest,
    params: &[(String, HostTensor)],
    padded: &Padded,
    task: &RootTask,
) -> Result<Mat> {
    let rc = ModelConfig::from_manifest(manifest)?;
    let num_roots = manifest.pad_spec()?.component_cap - 1;
    mpnn_forward_with_config(&rc, params, padded, task, num_roots)
}

/// [`mpnn_forward_reference`] against an explicit [`ModelConfig`] —
/// usable without a manifest (the native engine's parity tests feed
/// their from-scratch parameters through this).
pub fn mpnn_forward_with_config(
    rc: &ModelConfig,
    params: &[(String, HostTensor)],
    padded: &Padded,
    task: &RootTask,
    num_roots: usize,
) -> Result<Mat> {
    let p = ParamMap::new(params);
    let g = &padded.graph;

    // Initial states (MapFeatures), via the staged encoder.
    let mut h: BTreeMap<String, Mat> = BTreeMap::new();
    for set in &rc.node_order {
        let n = g.num_nodes(set)?;
        let feats = &rc.features[set];
        if !feats.is_empty() {
            let mut xs = Vec::with_capacity(feats.len());
            let mut ws = Vec::with_capacity(feats.len());
            for fname in feats {
                let (dims, data) = g.node_set(set)?.feature(fname)?.as_f32()?;
                xs.push(Mat { rows: n, cols: dims[0], data: data.to_vec() });
                ws.push(p.mat(&format!("enc.{set}.{fname}.w"))?);
            }
            let wrefs: Vec<&Mat> = ws.iter().collect();
            let b = p.vec(&format!("enc.{set}.{}.b", feats[0]))?;
            let (state, _z) = encode_dense(&xs, &wrefs, &b);
            h.insert(set.clone(), state);
        } else if rc.id_embedding[set] {
            let (_, ids) = g.node_set(set)?.feature("#id")?.as_i64()?;
            let table = p.mat(&format!("emb.{set}"))?;
            let idx: Vec<i32> = ids.iter().map(|&i| i as i32).collect();
            h.insert(set.clone(), table.gather(&idx));
        } else {
            h.insert(set.clone(), Mat::zeros(n, rc.hidden));
        }
    }

    // GraphUpdate rounds (receiver = SOURCE; messages relu(W[s||r]+b)).
    for layer in 0..rc.layers {
        let mut new_h = h.clone();
        for (node_set, edge_list) in &rc.updates {
            let n_recv = g.num_nodes(node_set)?;
            let mut pooled = Vec::new();
            let mut edge_names: Vec<&String> = edge_list.iter().collect();
            edge_names.sort();
            for es in edge_names {
                let adj = &g.edge_set(es)?.adjacency;
                let src: Vec<i32> = adj.source.iter().map(|&x| x as i32).collect();
                let tgt: Vec<i32> = adj.target.iter().map(|&x| x as i32).collect();
                let send_set = &rc.edge_endpoints[es].1;
                // Fused gather→concat→MLP→pool; bit-for-bit equal to
                // the unfused sequence (edge_conv_unfused) but without
                // the four [num_edges, …] intermediates.
                pooled.push(edge_conv_fused(
                    &h[send_set],
                    &h[node_set],
                    &tgt,
                    &src,
                    &p.mat(&format!("l{layer}.{node_set}.{es}.msg.w"))?,
                    &p.vec(&format!("l{layer}.{node_set}.{es}.msg.b"))?,
                    n_recv,
                ));
            }
            let mut parts: Vec<&Mat> = vec![&h[node_set]];
            parts.extend(pooled.iter());
            let (mut next, _saved) = node_update(
                &parts,
                &p.mat(&format!("l{layer}.{node_set}.next.w"))?,
                &p.vec(&format!("l{layer}.{node_set}.next.b"))?,
            );
            // layer norm (the mag config enables it)
            if params.iter().any(|(n, _)| n == &format!("param.l{layer}.{node_set}.ln.scale")) {
                next.layer_norm(
                    &p.vec(&format!("l{layer}.{node_set}.ln.scale"))?,
                    &p.vec(&format!("l{layer}.{node_set}.ln.bias"))?,
                );
            }
            new_h.insert(node_set.clone(), next);
        }
        h = new_h;
    }

    // Root readout.
    let roots = root_indices(padded, &task.root_set, num_roots)?;
    let (logits, _root_states) =
        root_readout(&h[&task.root_set], &roots, &p.mat("head.w")?, &p.vec("head.b")?);
    debug_assert_eq!(logits.cols, rc.num_classes);
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_ops() {
        let a = Mat { rows: 2, cols: 3, data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let w = Mat { rows: 3, cols: 2, data: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0] };
        let c = a.matmul(&w);
        assert_eq!(c.data, vec![4.0, 5.0, 10.0, 11.0]);
        let g = a.gather(&[1, 0, 1]);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(0), &[4.0, 5.0, 6.0]);
        let s = a.segment_sum(&[0, 0], 2);
        assert_eq!(s.row(0), &[5.0, 7.0, 9.0]);
        assert_eq!(s.row(1), &[0.0, 0.0, 0.0]);
        let cc = Mat::concat_cols(&[&a, &a]);
        assert_eq!(cc.cols, 6);
        assert_eq!(cc.row(1), &[4.0, 5.0, 6.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn mat_transpose_and_reductions() {
        let a = Mat { rows: 2, cols: 3, data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.cols, 2);
        assert_eq!(t.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        // (A^T)^T == A
        assert_eq!(t.transpose().data, a.data);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
        let mut b = a.clone();
        b.add_assign(&a);
        assert_eq!(b.data, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0]);
        b.scale(0.5);
        assert_eq!(b.data, a.data);
    }

    /// The fused edge conv must reproduce the unfused oracle exactly —
    /// this is what keeps `mpnn_forward_reference` a valid bit-level
    /// reference for the AOT programs after the fusion. The tape
    /// variant must match too (it is the unfused sequence plus saves).
    #[test]
    fn fused_edge_conv_matches_unfused_bitexact() {
        use crate::util::proptest::check;
        check("edge_conv fused == unfused == tape", 40, |rng| {
            let n_send = 1 + rng.uniform(12);
            let n_recv = 1 + rng.uniform(12);
            let n_edges = rng.uniform(40);
            let d_in = 1 + rng.uniform(6);
            let d_out = 1 + rng.uniform(6);
            let mk = |rows: usize, cols: usize, rng: &mut crate::util::rng::Rng| Mat {
                rows,
                cols,
                data: (0..rows * cols)
                    .map(|_| {
                        // Mix in exact zeros to exercise matmul's
                        // zero-activation skip on both paths.
                        if rng.chance(0.2) {
                            0.0
                        } else {
                            rng.range_f32(-2.0, 2.0)
                        }
                    })
                    .collect(),
            };
            let sender_h = mk(n_send, d_in, rng);
            let receiver_h = mk(n_recv, d_in, rng);
            let w = mk(2 * d_in, d_out, rng);
            let b: Vec<f32> = (0..d_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let sidx: Vec<i32> = (0..n_edges).map(|_| rng.uniform(n_send) as i32).collect();
            let ridx: Vec<i32> = (0..n_edges).map(|_| rng.uniform(n_recv) as i32).collect();
            let want = edge_conv_unfused(&sender_h, &receiver_h, &sidx, &ridx, &w, &b, n_recv);
            let got = edge_conv_fused(&sender_h, &receiver_h, &sidx, &ridx, &w, &b, n_recv);
            let (tape, saved) =
                edge_conv_tape(&sender_h, &receiver_h, &sidx, &ridx, &w, &b, n_recv);
            assert_eq!(want.rows, got.rows);
            assert_eq!(want.cols, got.cols);
            assert_eq!(saved.x_edge.rows, n_edges);
            assert_eq!(saved.z_msg.cols, d_out);
            for (i, (x, y)) in want.data.iter().zip(&got.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
            }
            for (i, (x, y)) in want.data.iter().zip(&tape.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "tape element {i}: {x} vs {y}");
            }
        });
    }

    #[test]
    fn staged_encode_and_update_match_inline_sequence() {
        // encode_dense == (Σ x@W) + b then relu; node_update ==
        // concat→matmul→bias→relu — the exact inline sequence the
        // reference used before the staging refactor.
        let x = Mat { rows: 2, cols: 2, data: vec![1.0, -1.0, 0.5, 2.0] };
        let w = Mat { rows: 2, cols: 2, data: vec![1.0, 0.0, 0.0, 1.0] };
        let b = vec![0.1, -10.0];
        let (h, z) = encode_dense(std::slice::from_ref(&x), &[&w], &b);
        let mut want = x.matmul(&w);
        want.add_bias(&b);
        assert_eq!(z.data, want.data, "pre-activation saved");
        want.relu();
        assert_eq!(h.data, want.data);
        assert!(h.data.iter().all(|&v| v >= 0.0));

        let (h2, saved) = node_update(&[&x, &h], &Mat { rows: 4, cols: 1, data: vec![1.0; 4] }, &[-0.5]);
        assert_eq!(saved.x_cat.cols, 4);
        assert_eq!(saved.z.cols, 1);
        let mut want2 = saved.x_cat.matmul(&Mat { rows: 4, cols: 1, data: vec![1.0; 4] });
        want2.add_bias(&[-0.5]);
        want2.relu();
        assert_eq!(h2.data, want2.data);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut m = Mat { rows: 1, cols: 4, data: vec![1.0, 2.0, 3.0, 4.0] };
        m.layer_norm(&[1.0; 4], &[0.0; 4]);
        let mu: f32 = m.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        let var: f32 = m.data.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn model_config_from_config_json() {
        let text = r#"{
          "model": {"hidden_dim": 8, "message_dim": 4, "num_layers": 2,
                    "updates": {"paper": ["cites"]}},
          "schema": {
            "node_sets": {
              "paper": {"features": {"feat": 16}},
              "venue": {"id_embedding": true, "cardinality": 5}
            },
            "edge_sets": {"cites": ["paper", "paper"]}
          },
          "train": {"num_classes": 3}
        }"#;
        let cfg = ModelConfig::from_config(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.arch, "mpnn", "no type/arch key defaults to mpnn");
        assert_eq!(cfg.att_dim, cfg.message, "att_dim defaults to message_dim");
        assert_eq!(cfg.sage_reduce, "mean");
        assert_eq!(cfg.hidden, 8);
        assert_eq!(cfg.message, 4);
        assert_eq!(cfg.layers, 2);
        assert_eq!(cfg.num_classes, 3);
        assert_eq!(cfg.node_order, vec!["paper".to_string(), "venue".to_string()]);
        assert_eq!(cfg.features["paper"], vec!["feat".to_string()]);
        assert_eq!(cfg.feature_dims["paper"]["feat"], 16);
        assert!(cfg.id_embedding["venue"]);
        assert!(!cfg.id_embedding["paper"]);
        assert_eq!(cfg.cardinality["venue"], 5);
        assert_eq!(cfg.edge_endpoints["cites"], ("paper".to_string(), "paper".to_string()));
        assert_eq!(cfg.updates["paper"], vec!["cites".to_string()]);
    }

    #[test]
    fn model_config_parses_zoo_keys() {
        let text = r#"{
          "model": {"type": "gatv2", "hidden_dim": 8, "message_dim": 4,
                    "att_dim": 6, "sage_reduce": "max", "num_layers": 1,
                    "updates": {"paper": ["cites"]}},
          "schema": {
            "node_sets": {"paper": {"features": {"feat": 16}}},
            "edge_sets": {"cites": ["paper", "paper"]}
          },
          "train": {"num_classes": 3}
        }"#;
        let cfg = ModelConfig::from_config(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.arch, "gatv2");
        assert_eq!(cfg.att_dim, 6);
        assert_eq!(cfg.sage_reduce, "max");
        let sage = cfg.with_arch("sage");
        assert_eq!(sage.arch, "sage");
        // type/arch agreement is enforced; equal duplicates are fine.
        let dup = text.replace(r#""type": "gatv2","#, r#""type": "gatv2", "arch": "gcn","#);
        let err = ModelConfig::from_config(&Json::parse(&dup).unwrap());
        assert!(err.is_err(), "conflicting type/arch must be rejected");
        let same = text.replace(r#""type": "gatv2","#, r#""type": "gatv2", "arch": "gatv2","#);
        assert!(ModelConfig::from_config(&Json::parse(&same).unwrap()).is_ok());
        // A legacy `arch` key alone selects only "mpnn": the AOT
        // engine's gcn/sage/gatv2 are different models, so reusing an
        // AOT config with the native engine must not silently build a
        // lookalike — it errors, demanding an explicit model.type.
        let legacy = text.replace(r#""type": "gatv2","#, r#""arch": "gcn","#);
        let err = ModelConfig::from_config(&Json::parse(&legacy).unwrap());
        assert!(err.is_err(), "non-mpnn arch without type must be rejected");
        assert!(err.err().unwrap().to_string().contains("model.type"));
        let legacy_mpnn = text.replace(r#""type": "gatv2","#, r#""arch": "mpnn","#);
        let cfg = ModelConfig::from_config(&Json::parse(&legacy_mpnn).unwrap()).unwrap();
        assert_eq!(cfg.arch, "mpnn");
    }

    /// Typos in the `model` block (`att_dims`) must be structured
    /// errors naming the key, never a silent fall-back to defaults.
    #[test]
    fn unknown_model_key_is_rejected_by_name() {
        let text = r#"{
          "model": {"hidden_dim": 8, "message_dim": 4, "num_layers": 2,
                    "att_dims": 6, "updates": {"paper": ["cites"]}},
          "schema": {
            "node_sets": {"paper": {"features": {"feat": 16}}},
            "edge_sets": {"cites": ["paper", "paper"]}
          },
          "train": {"num_classes": 3}
        }"#;
        let err = ModelConfig::from_config(&Json::parse(text).unwrap())
            .expect_err("att_dims must be rejected");
        let msg = err.to_string();
        assert!(msg.contains("att_dims"), "{msg}");
        assert!(msg.contains("model"), "{msg}");
    }

    #[test]
    fn task_block_parses_and_validates() {
        let base = r#"{
          "model": {"hidden_dim": 8, "message_dim": 4, "num_layers": 1,
                    "updates": {"paper": ["cites"]}},
          "schema": {
            "node_sets": {"paper": {"features": {"feat": 16}}},
            "edge_sets": {"cites": ["paper", "paper"]}
          },
          "train": {"num_classes": 3}TASK
        }"#;
        // No task block → root classification defaults.
        let cfg =
            ModelConfig::from_config(&Json::parse(&base.replace("TASK", "")).unwrap()).unwrap();
        assert_eq!(cfg.task.kind, "root_classification");
        assert_eq!(cfg.task.root_set, "paper");

        // A full link-prediction block round-trips.
        let lp = base.replace(
            "TASK",
            r#", "task": {"type": "link_prediction", "edge_set": "cites",
                 "readout": "hadamard", "loss": "margin", "margin": 0.5,
                 "negatives": 6, "hits_k": 2, "holdout_fraction": 0.2,
                 "split_seed": 9, "mlp_dim": 12}"#,
        );
        let cfg = ModelConfig::from_config(&Json::parse(&lp).unwrap()).unwrap();
        assert_eq!(cfg.task.kind, "link_prediction");
        assert_eq!(cfg.task.readout, "hadamard");
        assert_eq!(cfg.task.loss, "margin");
        assert_eq!(cfg.task.negatives, 6);
        assert_eq!(cfg.task.hits_k, 2);
        assert_eq!(cfg.task.mlp_dim, 12);
        assert!((cfg.task.holdout_fraction - 0.2).abs() < 1e-12);

        // Unknown task key, unknown kind, bad enum values, bad knobs:
        // all structured errors naming the offender.
        for (bad, needle) in [
            (r#", "task": {"type": "link_prediction", "negativs": 4}"#, "negativs"),
            (r#", "task": {"type": "edge_classification"}"#, "edge_classification"),
            (r#", "task": {"type": "link_prediction", "readout": "bilinear"}"#, "bilinear"),
            (r#", "task": {"type": "link_prediction", "loss": "nce"}"#, "nce"),
            (r#", "task": {"type": "link_prediction", "negatives": 0}"#, "negatives"),
            (
                r#", "task": {"type": "link_prediction", "holdout_fraction": 1.5}"#,
                "holdout_fraction",
            ),
            (r#", "task": {"type": "graph_regression", "target_scale": 0.0}"#, "target_scale"),
        ] {
            let text = base.replace("TASK", bad);
            let err = match ModelConfig::from_config(&Json::parse(&text).unwrap()) {
                Err(e) => e,
                Ok(_) => panic!("corrupted task block accepted: {bad}"),
            };
            let msg = err.to_string();
            assert!(msg.contains(needle), "error {msg:?} does not name {needle:?}");
        }
    }

    #[test]
    fn mag_model_config_is_consistent() {
        let mag = crate::synth::mag::MagConfig::tiny();
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 2);
        // Every updated node set must be the SOURCE endpoint of each of
        // its pooled edge sets (receiver = SOURCE convention).
        for (node_set, edges) in &cfg.updates {
            for es in edges {
                assert_eq!(&cfg.edge_endpoints[es].0, node_set, "{es} receiver");
            }
        }
        // Every node set has features/id_embedding entries.
        for set in &cfg.node_order {
            assert!(cfg.features.contains_key(set));
            assert!(cfg.id_embedding.contains_key(set));
        }
        assert_eq!(cfg.feature_dims["paper"]["feat"], mag.feature_dim);
        assert_eq!(cfg.cardinality["institution"], mag.num_institutions);
        assert_eq!(cfg.num_classes, mag.num_classes);
    }
}
