//! Rooted-subgraph sampling (paper §6.1, §8.2).
//!
//! A [`spec::SamplingSpec`] describes which edge sets to expand through,
//! how many neighbors to keep, and with what strategy — built fluently
//! with [`spec::SamplingSpecBuilder`] exactly as Figure 6 does. The spec
//! compiles to the op-plan of appendix A.6.2 (`SEED->paper`,
//! `paper->paper`, `(paper->paper|SEED->paper)->author`, …).
//!
//! Two executors share the plan semantics:
//! * [`inmem::InMemorySampler`] — the §6.1.2 medium-scale path: plan
//!   execution over the whole [`crate::store::GraphStore`] on one
//!   thread, generating GraphTensors on demand.
//! * [`distributed`] — the §6.1.1 large-scale path: **Algorithm 1**,
//!   stage-wise frontier expansion over the sharded store with
//!   group-by-sample-id, node dedup, feature join, and GraphTensor
//!   creation, driven by the [`crate::coordinator`] leader/worker fleet.

pub mod distributed;
pub mod inmem;
pub mod spec;

use std::collections::BTreeMap;

use crate::graph::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use crate::{Error, Result};

pub use distributed::RetryPolicy;

/// Execution knobs for the sampling engine, threaded through the
/// pipeline's sampling stage and the serving batcher.
///
/// `threads == 0` or `1` means single-threaded execution — the oracle
/// path every parallel mode is bit-for-bit equivalent to (neighbor
/// selection draws from an RNG keyed by `(plan_seed, seed, op, node)`,
/// so scheduling never influences results; see `inmem::edge_rng`).
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Worker threads for batch sampling (shard fanout + per-seed
    /// subgraph assembly). 0/1 = serial.
    pub threads: usize,
    /// Seeds per parallel wave when sampling is streamed (the pipeline
    /// provider samples ahead in waves of this size).
    pub chunk_size: usize,
    /// Per-RPC retry policy against the sharded store.
    pub retry: RetryPolicy,
}

impl Default for SamplerConfig {
    fn default() -> SamplerConfig {
        SamplerConfig { threads: 1, chunk_size: 64, retry: RetryPolicy::default() }
    }
}

impl SamplerConfig {
    /// Convenience: a config with `threads` workers, defaults elsewhere.
    pub fn with_threads(threads: usize) -> SamplerConfig {
        SamplerConfig { threads, ..SamplerConfig::default() }
    }

    /// Whether this config asks for parallel execution.
    pub fn parallel(&self) -> bool {
        self.threads > 1
    }
}

/// Edges collected for one sample during plan execution, keyed by edge
/// set: (source original id, target original id).
pub type EdgeAcc = BTreeMap<String, Vec<(u32, u32)>>;

/// Assemble a rooted GraphTensor from accumulated edges.
///
/// This is the `DeduplicateNodes` + `lookup_features` +
/// `create_graph_tensors` tail of Algorithm 1, shared by both samplers:
/// * node ids are deduplicated per node set (the seed is always index 0
///   of the seed node set);
/// * features are fetched via `lookup` (store gather or sharded RPC);
/// * every node set gets an `"#id"` i64 feature with original ids
///   (A.6.1's convention), so embedding-table models can key on them;
/// * context records the `"seed"` id.
pub fn assemble_subgraph<F>(
    schema: &crate::schema::GraphSchema,
    seed_set: &str,
    seed: u32,
    edges: &EdgeAcc,
    lookup: F,
) -> Result<GraphTensor>
where
    F: FnMut(&str, &[u32]) -> Result<BTreeMap<String, Feature>>,
{
    assemble_subgraph_seeds(schema, seed_set, &[seed], edges, lookup)
}

/// [`assemble_subgraph`] generalized to a *seed list* — the pair/multi
/// rooted form link prediction samples (`[source, target,
/// negatives…]`). The seeds are interned first, **in list order**, so
/// seed `k` is node index `k` of the seed node set (the "seed first"
/// convention extended to "seeds first"); the context `"seed"` feature
/// records the first seed. Duplicate seeds are rejected (they would
/// silently break the positional convention).
pub fn assemble_subgraph_seeds<F>(
    schema: &crate::schema::GraphSchema,
    seed_set: &str,
    seeds: &[u32],
    edges: &EdgeAcc,
    mut lookup: F,
) -> Result<GraphTensor>
where
    F: FnMut(&str, &[u32]) -> Result<BTreeMap<String, Feature>>,
{
    let Some(&first_seed) = seeds.first() else {
        return Err(Error::Sampler("assemble_subgraph_seeds: empty seed list".into()));
    };
    // Dedup nodes per set, seeds first (in order).
    let mut node_ids: BTreeMap<String, Vec<u32>> = BTreeMap::new();
    let mut node_index: BTreeMap<String, BTreeMap<u32, u32>> = BTreeMap::new();
    {
        let ids = node_ids.entry(seed_set.to_string()).or_default();
        let index = node_index.entry(seed_set.to_string()).or_default();
        for (k, &s) in seeds.iter().enumerate() {
            if index.insert(s, k as u32).is_some() {
                return Err(Error::Sampler(format!(
                    "assemble_subgraph_seeds: duplicate seed {s} in the seed list"
                )));
            }
            ids.push(s);
        }
    }
    let intern = |set: &str, id: u32, ids: &mut BTreeMap<String, Vec<u32>>, idx: &mut BTreeMap<String, BTreeMap<u32, u32>>| -> u32 {
        let index = idx.entry(set.to_string()).or_default();
        if let Some(&i) = index.get(&id) {
            return i;
        }
        let list = ids.entry(set.to_string()).or_default();
        let i = list.len() as u32;
        list.push(id);
        index.insert(id, i);
        i
    };

    // Local edge lists with interned indices, dedup per edge set.
    let mut local_edges: BTreeMap<String, (Vec<u32>, Vec<u32>)> = BTreeMap::new();
    for (edge_set, pairs) in edges {
        let es_spec = schema.edge_set(edge_set)?;
        let mut seen = std::collections::HashSet::new();
        let (src_list, tgt_list) = local_edges.entry(edge_set.clone()).or_default();
        for &(s, t) in pairs {
            if !seen.insert((s, t)) {
                continue; // duplicate edge from overlapping ops
            }
            let si = intern(&es_spec.source, s, &mut node_ids, &mut node_index);
            let ti = intern(&es_spec.target, t, &mut node_ids, &mut node_index);
            src_list.push(si);
            tgt_list.push(ti);
        }
    }

    // Every node set in the schema appears in the output (possibly
    // empty), so downstream batching sees a uniform structure.
    let mut node_sets = BTreeMap::new();
    for (set_name, _) in &schema.node_sets {
        let ids = node_ids.get(set_name).cloned().unwrap_or_default();
        let mut ns = NodeSet::new(vec![ids.len()]);
        ns.features = lookup(set_name, &ids)?;
        ns.features
            .insert("#id".into(), Feature::i64_vec(ids.iter().map(|&i| i as i64).collect()));
        node_sets.insert(set_name.clone(), ns);
    }
    let mut edge_sets = BTreeMap::new();
    for (set_name, spec) in &schema.edge_sets {
        let (source, target) = local_edges.remove(set_name).unwrap_or_default();
        edge_sets.insert(
            set_name.clone(),
            EdgeSet::new(
                vec![source.len()],
                Adjacency {
                    source_set: spec.source.clone(),
                    target_set: spec.target.clone(),
                    source,
                    target,
                },
            ),
        );
    }
    let context =
        Context::default().with_feature("seed", Feature::i64_vec(vec![first_seed as i64]));
    let g = GraphTensor::from_pieces(context, node_sets, edge_sets)?;
    // Shared tail of every sampler path (serial, parallel, in-memory),
    // so each assembled subgraph is counted exactly once.
    crate::obs_counter!(crate::obs::metrics::names::SAMPLER_SUBGRAPHS).inc();
    Ok(g)
}

/// Shared validation: the sampling spec's edge sets must exist in the
/// schema and chain compatibly (op inputs produce the op's source set).
pub fn validate_spec(
    schema: &crate::schema::GraphSchema,
    spec: &spec::SamplingSpec,
) -> Result<()> {
    if !schema.node_sets.contains_key(&spec.seed_node_set) {
        return Err(Error::Sampler(format!(
            "seed node set {:?} not in schema",
            spec.seed_node_set
        )));
    }
    // op name -> node set produced
    let mut produces: BTreeMap<&str, &str> = BTreeMap::new();
    produces.insert(spec.seed_op.as_str(), spec.seed_node_set.as_str());
    for op in &spec.ops {
        let es = schema
            .edge_set(&op.edge_set)
            .map_err(|_| Error::Sampler(format!("edge set {:?} not in schema", op.edge_set)))?;
        for input in &op.input_ops {
            let Some(&set) = produces.get(input.as_str()) else {
                return Err(Error::Sampler(format!(
                    "op {:?} references unknown input {:?}",
                    op.op_name, input
                )));
            };
            if set != es.source {
                return Err(Error::Sampler(format!(
                    "op {:?}: input {input:?} yields {set:?} but edge set {:?} starts at {:?}",
                    op.op_name, op.edge_set, es.source
                )));
            }
        }
        if op.sample_size == 0 {
            return Err(Error::Sampler(format!("op {:?}: sample_size 0", op.op_name)));
        }
        produces.insert(op.op_name.as_str(), es.target.as_str());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::mag::{generate, mag_schema, MagConfig};

    #[test]
    fn assemble_minimal_subgraph() {
        let cfg = MagConfig::tiny();
        let ds = generate(&cfg);
        let schema = mag_schema(&cfg);
        let mut edges = EdgeAcc::new();
        edges.insert("cites".into(), vec![(0, 1), (0, 2), (0, 1)]); // dup edge
        let g = assemble_subgraph(&schema, "paper", 0, &edges, |set, ids| {
            Ok(ds.store.node_column(set).unwrap().gather(ids))
        })
        .unwrap();
        assert_eq!(g.num_nodes("paper").unwrap(), 3);
        assert_eq!(g.num_edges("cites").unwrap(), 2, "duplicate edge removed");
        // Seed is node 0.
        let ids = g.node_set("paper").unwrap().feature("#id").unwrap();
        let (_, id_vals) = ids.as_i64().unwrap();
        assert_eq!(id_vals[0], 0);
        // Seed in context.
        let (_, s) = g.context.feature("seed").unwrap().as_i64().unwrap();
        assert_eq!(s, &[0]);
        // All schema sets present even if empty.
        assert_eq!(g.num_nodes("institution").unwrap(), 0);
        assert_eq!(g.num_edges("writes").unwrap(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn assemble_preserves_edge_endpoints() {
        let cfg = MagConfig::tiny();
        let ds = generate(&cfg);
        let schema = mag_schema(&cfg);
        let mut edges = EdgeAcc::new();
        edges.insert("written".into(), vec![(5, 7), (5, 9)]);
        edges.insert("affiliated_with".into(), vec![(7, 1), (9, 1)]);
        let g = assemble_subgraph(&schema, "paper", 5, &edges, |set, ids| {
            Ok(ds.store.node_column(set).unwrap().gather(ids))
        })
        .unwrap();
        assert_eq!(g.num_nodes("paper").unwrap(), 1);
        assert_eq!(g.num_nodes("author").unwrap(), 2);
        assert_eq!(g.num_nodes("institution").unwrap(), 1);
        // written edges go paper(0) -> authors(0,1)
        let es = g.edge_set("written").unwrap();
        assert_eq!(es.adjacency.source, vec![0, 0]);
        assert_eq!(es.adjacency.target, vec![0, 1]);
        // #id features carry original ids for embedding lookup.
        let (_, aid) = g.node_set("author").unwrap().feature("#id").unwrap().as_i64().unwrap();
        assert_eq!(aid, &[7, 9]);
    }
}
