//! In-memory sampling (paper §6.1.2).
//!
//! For datasets that fit on one machine, the sampler executes the plan
//! directly over the [`GraphStore`] CSR, generating rooted GraphTensors
//! on the fly (they are "typically not persisted" — the pipeline
//! consumes them on demand).
//!
//! **Scheduling-independent determinism**: neighbor selection for
//! (seed, op, node) draws from an RNG derived as
//! `mix(plan_seed, seed, op_index, node)`, so the in-memory sampler,
//! the distributed sampler and any worker interleaving all produce
//! bit-identical subgraphs for the same plan seed — asserted by the
//! cross-implementation equivalence tests in `distributed.rs`.
//!
//! **CSR fast path**: construction compiles the plan — each op's edge
//! set is materialized once as a shared [`crate::graph::csr::Csr`]
//! view, so the per-seed hot loop reads neighbor slices straight out
//! of CSR rows instead of re-resolving columns through per-lookup hash
//! joins (and without allocating a `Vec` per lookup, as the generic
//! [`expand_one`] closure interface must). [`expand_one`] remains the
//! oracle the fast path is tested against. Cloning the sampler is
//! cheap (heavy state is `Arc`-shared), which is what lets
//! [`InMemorySampler::sample_batch_with_pool`] fan a batch of seeds
//! out across a [`ThreadPool`] — order-preserving and bit-for-bit
//! equal to serial sampling.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::spec::{SamplingSpec, Strategy};
use super::{assemble_subgraph, validate_spec, EdgeAcc, SamplerConfig};
use crate::graph::csr::Csr;
use crate::graph::GraphTensor;
use crate::store::GraphStore;
use crate::util::rng::{mix64, Rng};
use crate::util::threadpool::ThreadPool;
use crate::Result;

/// Derive the per-(seed, op, node) sampling RNG. Shared with the
/// distributed executor.
pub fn edge_rng(plan_seed: u64, seed_node: u32, op_index: usize, node: u32) -> Rng {
    Rng::new(mix64(mix64(plan_seed, seed_node as u64), mix64(op_index as u64, node as u64)))
}

/// Select up to `k` neighbors under a strategy. Shared with the
/// distributed executor.
pub fn select_neighbors(
    neighbors: &[u32],
    k: usize,
    strategy: Strategy,
    rng: &mut Rng,
) -> Vec<u32> {
    if neighbors.len() <= k {
        return neighbors.to_vec();
    }
    match strategy {
        Strategy::TopK => neighbors[..k].to_vec(),
        Strategy::RandomUniform => {
            rng.sample_distinct(neighbors.len(), k).into_iter().map(|i| neighbors[i]).collect()
        }
    }
}

/// Execute the plan for one seed against a CSR-neighbor closure.
///
/// `neighbors(op_index, edge_set, node)` returns the out-neighbors; the
/// in-memory path reads the store directly, the distributed path issues
/// shard RPCs with retries.
pub fn expand_one<F>(
    spec: &SamplingSpec,
    plan_seed: u64,
    seed: u32,
    mut neighbors: F,
) -> Result<EdgeAcc>
where
    F: FnMut(usize, &str, u32) -> Result<Vec<u32>>,
{
    // op name -> nodes produced (in first-seen order, deduped).
    let mut produced: BTreeMap<&str, Vec<u32>> = BTreeMap::new();
    produced.insert(spec.seed_op.as_str(), vec![seed]);
    let mut edges = EdgeAcc::new();
    for (op_idx, op) in spec.ops.iter().enumerate() {
        // Union of the input frontiers, first-occurrence order.
        let mut inputs = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for input in &op.input_ops {
            if let Some(nodes) = produced.get(input.as_str()) {
                for &n in nodes {
                    if seen.insert(n) {
                        inputs.push(n);
                    }
                }
            }
        }
        let mut out_nodes = Vec::new();
        let mut out_seen = std::collections::HashSet::new();
        let acc = edges.entry(op.edge_set.clone()).or_default();
        for &node in &inputs {
            let nbrs = neighbors(op_idx, &op.edge_set, node)?;
            let mut rng = edge_rng(plan_seed, seed, op_idx, node);
            for t in select_neighbors(&nbrs, op.sample_size, op.strategy, &mut rng) {
                acc.push((node, t));
                if out_seen.insert(t) {
                    out_nodes.push(t);
                }
            }
        }
        produced.insert(op.op_name.as_str(), out_nodes);
    }
    Ok(edges)
}

/// The §6.1.2 sampler.
#[derive(Clone)]
pub struct InMemorySampler {
    store: Arc<GraphStore>,
    spec: Arc<SamplingSpec>,
    plan_seed: u64,
    /// Per-op CSR view of the op's edge set (index-aligned with
    /// `spec.ops`; ops over the same edge set share one view).
    csr: Vec<Arc<Csr>>,
}

impl InMemorySampler {
    pub fn new(store: Arc<GraphStore>, spec: SamplingSpec, plan_seed: u64) -> Result<InMemorySampler> {
        validate_spec(&store.schema, &spec)?;
        // Compile the plan: one validated CSR view per distinct edge
        // set, shared by every op that expands through it.
        let mut by_edge_set: BTreeMap<String, Arc<Csr>> = BTreeMap::new();
        let mut csr = Vec::with_capacity(spec.ops.len());
        for op in &spec.ops {
            if let Some(view) = by_edge_set.get(&op.edge_set) {
                csr.push(Arc::clone(view));
                continue;
            }
            let ec = store.edge_column(&op.edge_set)?;
            let n_src = ec.offsets.len() - 1;
            let n_tgt = store.node_count(&ec.target_set)?;
            let mut keyed = Vec::with_capacity(ec.num_edges());
            for s in 0..n_src {
                for _ in ec.offsets[s]..ec.offsets[s + 1] {
                    keyed.push(s as u32);
                }
            }
            let view = Arc::new(Csr::build(&op.edge_set, &keyed, &ec.targets, n_src, n_tgt)?);
            by_edge_set.insert(op.edge_set.clone(), Arc::clone(&view));
            csr.push(view);
        }
        Ok(InMemorySampler { store, spec: Arc::new(spec), plan_seed, csr })
    }

    pub fn spec(&self) -> &SamplingSpec {
        &self.spec
    }

    /// Sample the rooted subgraph for one seed node.
    pub fn sample(&self, seed: u32) -> Result<GraphTensor> {
        let _span = crate::span!("sampler/sample", seed = seed);
        let edges = self.expand_fast(seed);
        assemble_subgraph(&self.store.schema, &self.spec.seed_node_set, seed, &edges, |set, ids| {
            Ok(self.store.node_column(set)?.gather(ids))
        })
    }

    /// CSR fast path of [`expand_one`]: identical iteration order, RNG
    /// keying and selection — only the neighbor lookups differ (direct
    /// CSR row slices instead of per-lookup column resolution plus a
    /// `Vec` allocation). `fast_path_matches_generic_oracle` pins the
    /// bit-for-bit equivalence.
    fn expand_fast(&self, seed: u32) -> EdgeAcc {
        let mut produced: BTreeMap<&str, Vec<u32>> = BTreeMap::new();
        produced.insert(self.spec.seed_op.as_str(), vec![seed]);
        let mut edges = EdgeAcc::new();
        for (op_idx, op) in self.spec.ops.iter().enumerate() {
            let view = &self.csr[op_idx];
            let mut inputs = Vec::new();
            let mut seen = std::collections::HashSet::new();
            for input in &op.input_ops {
                if let Some(nodes) = produced.get(input.as_str()) {
                    for &n in nodes {
                        if seen.insert(n) {
                            inputs.push(n);
                        }
                    }
                }
            }
            let mut out_nodes = Vec::new();
            let mut out_seen = std::collections::HashSet::new();
            let acc = edges.entry(op.edge_set.clone()).or_default();
            for &node in &inputs {
                let nbrs = view.row_neighbors(node as usize);
                let mut rng = edge_rng(self.plan_seed, seed, op_idx, node);
                for t in select_neighbors(nbrs, op.sample_size, op.strategy, &mut rng) {
                    acc.push((node, t));
                    if out_seen.insert(t) {
                        out_nodes.push(t);
                    }
                }
            }
            produced.insert(op.op_name.as_str(), out_nodes);
        }
        edges
    }

    /// Sample one *multi-rooted* subgraph: the plan's expansion of
    /// every seed in `seeds`, merged into a single GraphTensor whose
    /// seed node set pins the seeds first, **in list order** (seed `k`
    /// = node index `k`). This is the pair form link prediction scores
    /// — `sample_seeds(&[u, v, negatives…])` puts the source at row 0
    /// and every candidate's *message-passed* state in the same
    /// component.
    ///
    /// Determinism: per-seed expansion uses the same
    /// `(plan_seed, seed, op, node)` RNG keying as [`Self::sample`], so
    /// each seed's edges are bit-identical to its single-seed expansion
    /// and `sample_seeds(&[s])` equals `sample(s)` exactly (pinned by a
    /// test below). Overlapping expansions dedup edges at assembly, the
    /// same rule the single-seed path applies to overlapping ops.
    pub fn sample_seeds(&self, seeds: &[u32]) -> Result<GraphTensor> {
        let _span = crate::span!("sampler/sample_seeds", seeds = seeds.len());
        // Seed ids are caller input (serving requests name them
        // directly): validate against the store before expansion, so a
        // hostile or stale id is a structured error instead of an
        // out-of-bounds panic inside a CSR row lookup.
        let n = self.store.node_count(&self.spec.seed_node_set)?;
        for &s in seeds {
            if s as usize >= n {
                return Err(crate::Error::Sampler(format!(
                    "seed {s} outside node set {:?} (cardinality {n})",
                    self.spec.seed_node_set
                )));
            }
        }
        let mut edges = EdgeAcc::new();
        for &s in seeds {
            for (es, pairs) in self.expand_fast(s) {
                edges.entry(es).or_default().extend(pairs);
            }
        }
        crate::sampler::assemble_subgraph_seeds(
            &self.store.schema,
            &self.spec.seed_node_set,
            seeds,
            &edges,
            |set, ids| Ok(self.store.node_column(set)?.gather(ids)),
        )
    }

    /// Sample many seeds (an iterator adapter for the pipeline).
    pub fn sample_many<'a>(
        &'a self,
        seeds: &'a [u32],
    ) -> impl Iterator<Item = Result<GraphTensor>> + 'a {
        seeds.iter().map(move |&s| self.sample(s))
    }

    /// Sample a batch of seeds fanned out over `pool`. Seeds are
    /// independent and selection is RNG-keyed, so the result is
    /// bit-for-bit identical to sampling serially, in seed order.
    pub fn sample_batch_with_pool(
        &self,
        seeds: &[u32],
        pool: &ThreadPool,
    ) -> Result<Vec<GraphTensor>> {
        let this = self.clone();
        let results = pool.map(seeds.to_vec(), move |s| this.sample(s));
        let mut out = Vec::with_capacity(results.len());
        for g in results {
            out.push(g?);
        }
        Ok(out)
    }

    /// Sample a batch under `cfg`: serial when `cfg.threads <= 1`,
    /// else on a transient pool of `cfg.threads` workers.
    pub fn sample_batch(&self, seeds: &[u32], cfg: &SamplerConfig) -> Result<Vec<GraphTensor>> {
        if !cfg.parallel() {
            return seeds.iter().map(|&s| self.sample(s)).collect();
        }
        let pool = ThreadPool::new(cfg.threads);
        self.sample_batch_with_pool(seeds, &pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::spec::{mag_sampling_spec_scaled, SamplingSpecBuilder};
    use crate::synth::mag::{generate, MagConfig};
    use crate::util::proptest::check;

    fn setup() -> (Arc<GraphStore>, SamplingSpec) {
        let ds = generate(&MagConfig::tiny());
        let spec = mag_sampling_spec_scaled(&ds.store.schema, 0.25).unwrap();
        (Arc::new(ds.store), spec)
    }

    #[test]
    fn sample_produces_rooted_subgraph() {
        let (store, spec) = setup();
        let s = InMemorySampler::new(store.clone(), spec, 42).unwrap();
        let g = s.sample(0).unwrap();
        g.validate().unwrap();
        let (_, ids) = g.node_set("paper").unwrap().feature("#id").unwrap().as_i64().unwrap();
        assert_eq!(ids[0], 0, "seed first");
        assert!(g.num_nodes("paper").unwrap() >= 1);
        // Features came along.
        let (dims, _) = g.node_set("paper").unwrap().feature("feat").unwrap().as_f32().unwrap();
        assert_eq!(dims, &[16]);
    }

    #[test]
    fn deterministic_per_plan_seed() {
        let (store, spec) = setup();
        let a = InMemorySampler::new(store.clone(), spec.clone(), 7).unwrap();
        let b = InMemorySampler::new(store.clone(), spec.clone(), 7).unwrap();
        let c = InMemorySampler::new(store, spec, 8).unwrap();
        for seed in [0u32, 5, 50] {
            assert_eq!(a.sample(seed).unwrap(), b.sample(seed).unwrap());
        }
        // Different plan seed gives (almost surely) different subgraphs
        // for a node with enough neighbors; just check not all equal.
        let same = (0..20u32)
            .filter(|&s| a.sample(s).unwrap() == c.sample(s).unwrap())
            .count();
        assert!(same < 20);
    }

    #[test]
    fn respects_sample_sizes() {
        let (store, _) = setup();
        let b = SamplingSpecBuilder::new(&store.schema, Strategy::RandomUniform);
        let seed = b.seed("paper").unwrap();
        let _cited = b.sample(&seed, 3, "cites").unwrap();
        let spec = b.build().unwrap();
        let s = InMemorySampler::new(store.clone(), spec, 1).unwrap();
        for seed_node in 0..60u32 {
            let g = s.sample(seed_node).unwrap();
            let n_edges = g.num_edges("cites").unwrap();
            assert!(n_edges <= 3, "at most k edges from the seed");
            let deg = store.edge_column("cites").unwrap().out_degree(seed_node);
            assert_eq!(n_edges, deg.min(3), "exactly min(degree, k) — no replacement");
        }
    }

    #[test]
    fn topk_is_prefix_of_adjacency() {
        let (store, _) = setup();
        let b = SamplingSpecBuilder::new(&store.schema, Strategy::TopK);
        let seed = b.seed("paper").unwrap();
        let _ = b.sample(&seed, 2, "cites").unwrap();
        let spec = b.build().unwrap();
        let s = InMemorySampler::new(store.clone(), spec, 1).unwrap();
        for seed_node in 0..40u32 {
            let g = s.sample(seed_node).unwrap();
            let want: Vec<i64> = store
                .edge_column("cites")
                .unwrap()
                .neighbors(seed_node)
                .iter()
                .take(2)
                .map(|&x| x as i64)
                .collect();
            let es = g.edge_set("cites").unwrap();
            let (_, pid) = g.node_set("paper").unwrap().feature("#id").unwrap().as_i64().unwrap();
            let got: Vec<i64> =
                es.adjacency.target.iter().map(|&t| pid[t as usize]).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn fast_path_matches_generic_oracle() {
        // The CSR fast path must be bit-for-bit the generic closure
        // path ([`expand_one`] + store lookups), seed by seed.
        let (store, spec) = setup();
        let s = InMemorySampler::new(store.clone(), spec.clone(), 42).unwrap();
        for seed in 0..40u32 {
            let edges = expand_one(&spec, 42, seed, |_, edge_set, node| {
                Ok(store.edge_column(edge_set)?.neighbors(node).to_vec())
            })
            .unwrap();
            let want = assemble_subgraph(
                &store.schema,
                &spec.seed_node_set,
                seed,
                &edges,
                |set, ids| Ok(store.node_column(set)?.gather(ids)),
            )
            .unwrap();
            assert_eq!(s.sample(seed).unwrap(), want, "seed {seed}");
        }
    }

    /// The multi-seed path degenerates to the single-seed sampler for a
    /// one-element list — bit-for-bit, across many seeds.
    #[test]
    fn sample_seeds_singleton_matches_sample_bitexact() {
        let (store, spec) = setup();
        let s = InMemorySampler::new(store, spec, 42).unwrap();
        for seed in 0..30u32 {
            assert_eq!(s.sample_seeds(&[seed]).unwrap(), s.sample(seed).unwrap(), "seed {seed}");
        }
    }

    #[test]
    fn sample_seeds_pins_seeds_first_in_order() {
        let (store, spec) = setup();
        let s = InMemorySampler::new(store, spec, 42).unwrap();
        let seeds = [7u32, 3, 55, 21];
        let g = s.sample_seeds(&seeds).unwrap();
        g.validate().unwrap();
        let (_, ids) = g.node_set("paper").unwrap().feature("#id").unwrap().as_i64().unwrap();
        for (k, &want) in seeds.iter().enumerate() {
            assert_eq!(ids[k], want as i64, "seed {k} pinned at row {k}");
        }
        // Context seed records the first of the list.
        let (_, ctx) = g.context.feature("seed").unwrap().as_i64().unwrap();
        assert_eq!(ctx, &[7]);
        // Deterministic.
        assert_eq!(g, s.sample_seeds(&seeds).unwrap());
        // Every single-seed expansion's edges are contained in the
        // union (per edge set, as (src_id, tgt_id) pairs).
        fn pair_ids(g: &GraphTensor, name: &str) -> std::collections::HashSet<(i64, i64)> {
            let es = g.edge_set(name).unwrap();
            let (_, sid) = g
                .node_set(&es.adjacency.source_set)
                .unwrap()
                .feature("#id")
                .unwrap()
                .as_i64()
                .unwrap();
            let (_, tid) = g
                .node_set(&es.adjacency.target_set)
                .unwrap()
                .feature("#id")
                .unwrap()
                .as_i64()
                .unwrap();
            es.adjacency
                .source
                .iter()
                .zip(&es.adjacency.target)
                .map(|(&a, &b)| (sid[a as usize], tid[b as usize]))
                .collect()
        }
        for &seed in &seeds {
            let single = s.sample(seed).unwrap();
            for name in single.edge_sets.keys() {
                assert!(
                    pair_ids(&single, name).is_subset(&pair_ids(&g, name)),
                    "seed {seed} edge set {name}: multi-seed union lost edges"
                );
            }
        }
    }

    #[test]
    fn sample_seeds_rejects_duplicates_empty_and_out_of_range() {
        let (store, spec) = setup();
        let s = InMemorySampler::new(store, spec, 42).unwrap();
        assert!(s.sample_seeds(&[]).is_err());
        let err = s.sample_seeds(&[4, 9, 4]).expect_err("duplicate seeds");
        assert!(err.to_string().contains("duplicate"), "{err}");
        // An out-of-range id (tiny MAG has 120 papers) is a structured
        // error, not a CSR slice panic — serving feeds raw client ids
        // through here.
        let err = s.sample_seeds(&[4, 9999]).expect_err("out-of-range seed");
        assert!(err.to_string().contains("9999"), "{err}");
    }

    #[test]
    fn batch_parallel_matches_serial() {
        let (store, spec) = setup();
        let s = InMemorySampler::new(store, spec, 11).unwrap();
        let seeds: Vec<u32> = (0..50).collect();
        let serial = s.sample_batch(&seeds, &SamplerConfig::default()).unwrap();
        assert_eq!(serial.len(), 50);
        for threads in [2usize, 8] {
            let par = s.sample_batch(&seeds, &SamplerConfig::with_threads(threads)).unwrap();
            assert_eq!(par, serial, "threads={threads}: order and bits preserved");
        }
        // Caller-owned pool variant.
        let pool = ThreadPool::new(4);
        let pooled = s.sample_batch_with_pool(&seeds, &pool).unwrap();
        assert_eq!(pooled, serial);
    }

    #[test]
    fn prop_subgraph_nodes_bounded_by_plan() {
        let (store, spec) = setup();
        let bound = spec.max_nodes_per_seed();
        let s = InMemorySampler::new(store.clone(), spec, 3).unwrap();
        check("subgraph ≤ plan bound", 30, |rng| {
            let seed = rng.uniform(120) as u32;
            let g = s.sample(seed).unwrap();
            let total: usize =
                g.node_sets.keys().map(|k| g.num_nodes(k).unwrap()).sum();
            assert!(total <= bound + 200, "nodes {total} vs bound {bound}");
            g.validate().unwrap();
        });
    }

    #[test]
    fn prop_all_edges_reference_sampled_nodes() {
        // assemble_subgraph validation covers index ranges; here check
        // that original-id endpoints really are store neighbors.
        let (store, spec) = setup();
        let s = InMemorySampler::new(store.clone(), spec, 9).unwrap();
        check("sampled edges exist in store", 20, |rng| {
            let seed = rng.uniform(120) as u32;
            let g = s.sample(seed).unwrap();
            for (name, es) in &g.edge_sets {
                let ec = store.edge_column(name).unwrap();
                let (_, src_ids) = g
                    .node_set(&es.adjacency.source_set)
                    .unwrap()
                    .feature("#id")
                    .unwrap()
                    .as_i64()
                    .unwrap();
                let (_, tgt_ids) = g
                    .node_set(&es.adjacency.target_set)
                    .unwrap()
                    .feature("#id")
                    .unwrap()
                    .as_i64()
                    .unwrap();
                for e in 0..es.total() {
                    let s_orig = src_ids[es.adjacency.source[e] as usize] as u32;
                    let t_orig = tgt_ids[es.adjacency.target[e] as usize] as u32;
                    assert!(
                        ec.neighbors(s_orig).contains(&t_orig),
                        "edge {name} {s_orig}->{t_orig} not in store"
                    );
                }
            }
        });
    }
}
