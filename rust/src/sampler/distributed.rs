//! Distributed sampling — **Algorithm 1** (paper §6.1.1).
//!
//! ```text
//! frontier_0 = Sample(S_0, E_p0)
//! for i in 1..=p.steps: frontier_i = Sample(frontier, E_pi)
//! edge_groups = frontier.GroupBy(sample_id)
//! edge_groups = DeduplicateNodes(edge_groups)
//! edges_with_features = lookup_features(edge_groups)
//! G = create_graph_tensors(edges_with_features)
//! ```
//!
//! [`sample_batch`] runs the plan **stage-wise over all seeds at once**
//! against the sharded store: each sampling op joins the current
//! frontier (a set of `(sample_id, node)` pairs) with one edge set, via
//! per-shard adjacency RPCs. Transient shard failures (injected by
//! [`crate::store::sharded::ShardedStore::with_failures`]) are retried
//! with bounded attempts — the resilience property §7 contrasts with
//! Graph-Learn. After expansion, edges are grouped by sample id, nodes
//! deduplicated, features joined, and GraphTensors assembled — shared
//! tail code with the in-memory sampler, which the equivalence tests
//! exploit.
//!
//! [`sample_batch_parallel`] is the same algorithm with **shard
//! fanout**: per stage, the frontier is grouped by owning shard and the
//! per-shard lookups run concurrently over [`crate::util::ThreadPool`],
//! then merge back in the serial iteration order. Because neighbor
//! selection is RNG-keyed per `(plan_seed, seed, op, node)` and the
//! merge order is fixed, the parallel engine is bit-for-bit equal to
//! [`sample_batch`] for every thread count — the determinism contract
//! DESIGN.md's sampling-engine section spells out.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::inmem::{edge_rng, select_neighbors};
use super::spec::SamplingSpec;
use super::{assemble_subgraph, validate_spec, EdgeAcc, SamplerConfig};
use crate::graph::GraphTensor;
use crate::store::sharded::ShardedStore;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// Retry policy for shard RPCs.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub max_attempts: usize,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 8 }
    }
}

impl RetryPolicy {
    /// Run `f`, retrying transient failures up to the limit.
    pub fn run<T, F: FnMut() -> Result<T>>(&self, f: F) -> Result<T> {
        self.run_ctx("RPC", f)
    }

    /// Run `f` with `what` naming the target (e.g. `"shard 3"`).
    ///
    /// On exhaustion the error is a structured [`Error::Graph`] that
    /// carries the target, the attempt count and the last underlying
    /// error. `max_attempts == 0` is a configuration error, not a
    /// silent clamp to one attempt: it fails immediately, before `f`
    /// ever runs, so a misconfigured policy cannot masquerade as a
    /// single-try one.
    pub fn run_ctx<T, F: FnMut() -> Result<T>>(&self, what: &str, f: F) -> Result<T> {
        self.run_lazy(|| what.to_string(), f)
    }

    /// [`run_ctx`](RetryPolicy::run_ctx) with the context built only
    /// when an error message is actually needed — hot loops (one call
    /// per adjacency RPC) must not pay a `format!` per lookup for a
    /// string that almost never gets used.
    pub fn run_lazy<T, C, F>(&self, what: C, mut f: F) -> Result<T>
    where
        C: Fn() -> String,
        F: FnMut() -> Result<T>,
    {
        if self.max_attempts == 0 {
            return Err(Error::Graph(format!(
                "{}: RetryPolicy {{ max_attempts: 0 }} permits no attempts",
                what()
            )));
        }
        let attempts = crate::obs_counter!(crate::obs::metrics::names::SAMPLER_RETRY_ATTEMPTS);
        // Error text is only collected on the failure path; the happy
        // path stays a counter increment away from the old code.
        let mut errors: Vec<String> = Vec::new();
        for _ in 0..self.max_attempts {
            attempts.inc();
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => errors.push(e.to_string()),
            }
        }
        crate::obs_counter!(crate::obs::metrics::names::SAMPLER_RETRY_EXHAUSTED).inc();
        let last = match errors.last() {
            Some(e) => e.clone(),
            None => "none recorded".to_string(),
        };
        // Tally distinct errors across the attempts so a flapping
        // shard (two alternating failure modes) is visible — the last
        // error alone used to hide everything before it.
        let mut tally: Vec<(&String, usize)> = Vec::new();
        for e in &errors {
            match tally.iter_mut().find(|(m, _)| *m == e) {
                Some(entry) => entry.1 += 1,
                None => tally.push((e, 1)),
            }
        }
        let tally_text =
            tally.iter().map(|(m, n)| format!("{n}x {m}")).collect::<Vec<_>>().join("; ");
        Err(Error::Graph(format!(
            "{} failed after {} attempts: last error: {last} (error tally: {tally_text})",
            what(),
            self.max_attempts
        )))
    }
}

/// Counters reported by a batch execution (per Fig. 4 pipeline stage).
#[derive(Debug, Default, Clone)]
pub struct SampleStats {
    pub seeds: usize,
    pub frontier_entries: usize,
    pub adjacency_rpcs: usize,
    pub retried_rpcs: usize,
    pub subgraphs: usize,
}

/// Per-op frontier construction shared by the serial oracle and the
/// parallel engine: per sample, the deduped union of the op's input
/// outputs in first-occurrence order. The bit-for-bit contract between
/// the two executors depends on both using exactly this ordering, so
/// it lives in one place.
fn build_frontiers(
    op: &super::spec::SamplingOp,
    produced: &BTreeMap<&str, Vec<Vec<u32>>>,
    num_samples: usize,
    stats: &mut SampleStats,
) -> Vec<Vec<u32>> {
    let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); num_samples];
    for (k, f) in frontier.iter_mut().enumerate() {
        let mut seen = std::collections::HashSet::new();
        for input in &op.input_ops {
            if let Some(per_sample) = produced.get(input.as_str()) {
                for &n in &per_sample[k] {
                    if seen.insert(n) {
                        f.push(n);
                    }
                }
            }
        }
        stats.frontier_entries += f.len();
    }
    frontier
}

/// Execute the plan for a batch of seeds over the sharded store.
///
/// Stage-wise (all samples advance together, as the distributed join
/// does), deterministic per `plan_seed` regardless of scheduling.
pub fn sample_batch(
    store: &ShardedStore,
    spec: &SamplingSpec,
    plan_seed: u64,
    seeds: &[u32],
    retry: &RetryPolicy,
) -> Result<(Vec<GraphTensor>, SampleStats)> {
    let _span = crate::span!("sampler/sample_batch", seeds = seeds.len());
    let schema = &store.store().schema;
    validate_spec(schema, spec)?;
    let mut stats = SampleStats { seeds: seeds.len(), ..Default::default() };

    // produced[op_name][sample_idx] = nodes, first-seen order.
    let mut produced: BTreeMap<&str, Vec<Vec<u32>>> = BTreeMap::new();
    produced.insert(spec.seed_op.as_str(), seeds.iter().map(|&s| vec![s]).collect());
    // Per-sample edge accumulators.
    let mut edges: Vec<EdgeAcc> = seeds.iter().map(|_| EdgeAcc::new()).collect();

    for (op_idx, op) in spec.ops.iter().enumerate() {
        let frontier = build_frontiers(op, &produced, seeds.len(), &mut stats);

        // Distributed Sample(): join frontier with the edge set.
        let src_set = schema.edge_set(&op.edge_set)?.source.clone();
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); seeds.len()];
        for (k, nodes) in frontier.iter().enumerate() {
            let mut out_seen = std::collections::HashSet::new();
            let acc = edges[k].entry(op.edge_set.clone()).or_default();
            for &node in nodes {
                stats.adjacency_rpcs += 1;
                let mut attempts = 0usize;
                let nbrs = retry.run_lazy(
                    || format!("shard {}", store.shard_of(&src_set, node)),
                    || {
                        attempts += 1;
                        store.neighbors(&op.edge_set, node).map(|n| n.to_vec())
                    },
                )?;
                stats.retried_rpcs += attempts - 1;
                let mut rng = edge_rng(plan_seed, seeds[k], op_idx, node);
                for t in select_neighbors(&nbrs, op.sample_size, op.strategy, &mut rng) {
                    acc.push((node, t));
                    if out_seen.insert(t) {
                        out[k].push(t);
                    }
                }
            }
        }
        produced.insert(op.op_name.as_str(), out);
    }

    // GroupBy(sample_id) is implicit in the per-sample accumulators;
    // dedup + feature join + tensor creation per sample.
    let mut graphs = Vec::with_capacity(seeds.len());
    for (k, &seed) in seeds.iter().enumerate() {
        let g = assemble_subgraph(schema, &spec.seed_node_set, seed, &edges[k], |set, ids| {
            retry.run_ctx("feature lookup", || store.lookup_features(set, ids))
        })?;
        graphs.push(g);
    }
    stats.subgraphs = graphs.len();
    Ok((graphs, stats))
}

/// One frontier entry during a fanout stage: the entry's position in
/// serial iteration order, the frontier node and its sample's seed.
type ShardItem = (usize, u32, u32);

/// Shard-fanout parallel execution of Algorithm 1 — the parallel
/// sampling engine.
///
/// Each sampling stage flattens the whole batch's frontier to
/// `(sample, node)` entries in the serial iteration order, groups them
/// by owning shard, and issues the per-shard adjacency lookups
/// **concurrently** on the thread pool (one task per shard, each
/// lookup under [`RetryPolicy::run_ctx`] tagged with its shard).
/// Neighbor selection draws from the RNG keyed by
/// `(plan_seed, seed, op, node)` — never from scheduling — and the
/// merge replays the entries in their original order, so the output is
/// **bit-for-bit equal** to [`sample_batch`] at every thread count,
/// including under injected shard failures. The per-seed assembly tail
/// (node dedup, feature join, GraphTensor creation) fans out over the
/// same pool, with `map`'s order preservation keeping seed order.
///
/// `cfg.threads <= 1` delegates to the single-threaded oracle. Pass an
/// existing `pool` to amortize worker spawn across calls (the serving
/// batcher does); otherwise a transient pool of `cfg.threads` workers
/// is created for this batch.
pub fn sample_batch_parallel(
    store: &Arc<ShardedStore>,
    spec: &SamplingSpec,
    plan_seed: u64,
    seeds: &[u32],
    cfg: &SamplerConfig,
    pool: Option<&ThreadPool>,
) -> Result<(Vec<GraphTensor>, SampleStats)> {
    if cfg.threads <= 1 {
        return sample_batch(store, spec, plan_seed, seeds, &cfg.retry);
    }
    let _span = crate::span!("sampler/sample_batch_parallel", seeds = seeds.len());
    let owned_pool;
    let pool = match pool {
        Some(p) => p,
        None => {
            owned_pool = ThreadPool::new(cfg.threads);
            &owned_pool
        }
    };
    let schema = &store.store().schema;
    validate_spec(schema, spec)?;
    let mut stats = SampleStats { seeds: seeds.len(), ..Default::default() };

    let mut produced: BTreeMap<&str, Vec<Vec<u32>>> = BTreeMap::new();
    produced.insert(spec.seed_op.as_str(), seeds.iter().map(|&s| vec![s]).collect());
    let mut edges: Vec<EdgeAcc> = seeds.iter().map(|_| EdgeAcc::new()).collect();

    for (op_idx, op) in spec.ops.iter().enumerate() {
        let frontier = build_frontiers(op, &produced, seeds.len(), &mut stats);

        // Flatten to entries in serial order, then group by shard.
        let src_set = schema.edge_set(&op.edge_set)?.source.clone();
        let mut entries: Vec<(usize, u32)> = Vec::new();
        for (k, nodes) in frontier.iter().enumerate() {
            for &node in nodes {
                entries.push((k, node));
            }
        }
        stats.adjacency_rpcs += entries.len();
        let mut by_shard: Vec<Vec<ShardItem>> = vec![Vec::new(); store.num_shards];
        for (idx, &(k, node)) in entries.iter().enumerate() {
            by_shard[store.shard_of(&src_set, node)].push((idx, node, seeds[k]));
        }
        let tasks: Vec<(usize, Vec<ShardItem>)> = by_shard
            .into_iter()
            .enumerate()
            .filter(|(_, items)| !items.is_empty())
            .collect();

        // Fan out: one task per shard with pending lookups.
        let store_c = Arc::clone(store);
        let edge_set = op.edge_set.clone();
        let sample_size = op.sample_size;
        let strategy = op.strategy;
        let retry = cfg.retry.clone();
        let results = pool.map(tasks, move |(shard, items): (usize, Vec<ShardItem>)| {
            let _fanout = crate::obs::timed(crate::obs_histogram!(
                crate::obs::metrics::names::SAMPLER_SHARD_FANOUT_SECONDS
            ));
            let _span = crate::span!("sampler/shard_fanout", shard = shard);
            let ctx = format!("shard {shard}");
            let mut rows = Vec::with_capacity(items.len());
            let mut retried = 0usize;
            for (idx, node, seed_node) in items {
                let mut attempts = 0usize;
                let nbrs = retry.run_ctx(&ctx, || {
                    attempts += 1;
                    store_c.neighbors(&edge_set, node).map(|n| n.to_vec())
                })?;
                retried += attempts - 1;
                let mut rng = edge_rng(plan_seed, seed_node, op_idx, node);
                rows.push((idx, select_neighbors(&nbrs, sample_size, strategy, &mut rng)));
            }
            Ok::<_, Error>((rows, retried))
        });

        // Deterministic merge: scatter per-entry selections (errors
        // surface in shard order, not completion order), then replay
        // the serial iteration order.
        let mut selected: Vec<Vec<u32>> = vec![Vec::new(); entries.len()];
        for r in results {
            let (rows, retried) = r?;
            stats.retried_rpcs += retried;
            for (idx, sel) in rows {
                selected[idx] = sel;
            }
        }
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); seeds.len()];
        let mut out_seen: Vec<std::collections::HashSet<u32>> =
            vec![std::collections::HashSet::new(); seeds.len()];
        for acc in edges.iter_mut() {
            acc.entry(op.edge_set.clone()).or_default();
        }
        for (idx, &(k, node)) in entries.iter().enumerate() {
            // Seeded for every sample by the or_default pass above.
            let acc = edges[k].get_mut(&op.edge_set).ok_or_else(|| {
                Error::Sampler(format!("edge accumulator missing {:?}", op.edge_set))
            })?;
            for &t in &selected[idx] {
                acc.push((node, t));
                if out_seen[k].insert(t) {
                    out[k].push(t);
                }
            }
        }
        produced.insert(op.op_name.as_str(), out);
    }

    // Assembly tail: dedup + feature join + tensor creation, one task
    // per seed; `map` preserves seed order.
    let items: Vec<(u32, EdgeAcc)> = seeds.iter().copied().zip(edges).collect();
    let store_c = Arc::clone(store);
    let seed_set = spec.seed_node_set.clone();
    let retry = cfg.retry.clone();
    let assembled = pool.map(items, move |(seed, acc): (u32, EdgeAcc)| {
        assemble_subgraph(&store_c.store().schema, &seed_set, seed, &acc, |set, ids| {
            retry.run_ctx("feature lookup", || store_c.lookup_features(set, ids))
        })
    });
    let mut graphs = Vec::with_capacity(seeds.len());
    for g in assembled {
        graphs.push(g?);
    }
    stats.subgraphs = graphs.len();
    Ok((graphs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::store::GraphStore;
    use crate::synth::mag::{generate, MagConfig};
    use std::sync::Arc;

    fn setup() -> (Arc<GraphStore>, SamplingSpec) {
        let ds = generate(&MagConfig::tiny());
        let spec = mag_sampling_spec_scaled(&ds.store.schema, 0.25).unwrap();
        (Arc::new(ds.store), spec)
    }

    #[test]
    fn equivalent_to_inmem_sampler() {
        // The cross-implementation invariant: Algorithm 1 over shards ==
        // single-threaded in-memory execution, bit for bit.
        let (store, spec) = setup();
        let inmem = InMemorySampler::new(store.clone(), spec.clone(), 42).unwrap();
        let sharded = ShardedStore::new(store.clone(), 4);
        let seeds: Vec<u32> = (0..30).collect();
        let (dist, stats) =
            sample_batch(&sharded, &spec, 42, &seeds, &RetryPolicy::default()).unwrap();
        assert_eq!(dist.len(), 30);
        assert_eq!(stats.subgraphs, 30);
        for (k, &s) in seeds.iter().enumerate() {
            assert_eq!(dist[k], inmem.sample(s).unwrap(), "seed {s}");
        }
    }

    #[test]
    fn resilient_to_transient_failures() {
        let (store, spec) = setup();
        let reliable = ShardedStore::new(store.clone(), 4);
        let flaky = ShardedStore::new(store.clone(), 4).with_failures(0.3, 999);
        let seeds: Vec<u32> = (0..20).collect();
        let (want, _) =
            sample_batch(&reliable, &spec, 7, &seeds, &RetryPolicy::default()).unwrap();
        let (got, stats) = sample_batch(&flaky, &spec, 7, &seeds, &RetryPolicy { max_attempts: 64 })
            .unwrap();
        assert_eq!(got, want, "results identical despite 30% transient failures");
        assert!(stats.retried_rpcs > 0, "failures actually happened and were retried");
    }

    #[test]
    fn fails_cleanly_when_retries_exhausted() {
        let (store, spec) = setup();
        // 100% failure: every request fails, retries can't save it.
        let dead = ShardedStore::new(store, 2).with_failures(1.0, 5);
        let err = sample_batch(&dead, &spec, 7, &[0, 1], &RetryPolicy { max_attempts: 3 });
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("after 3 attempts"), "{msg}");
    }

    #[test]
    fn stats_counted() {
        let (store, spec) = setup();
        let sharded = ShardedStore::new(store, 4);
        let seeds: Vec<u32> = (0..10).collect();
        let (_, stats) = sample_batch(&sharded, &spec, 1, &seeds, &RetryPolicy::default()).unwrap();
        assert_eq!(stats.seeds, 10);
        assert!(stats.adjacency_rpcs >= 10, "at least one expansion per seed");
        assert!(stats.frontier_entries >= stats.seeds);
        let (adj, feat, _) = sharded.total_requests();
        assert_eq!(adj as usize, stats.adjacency_rpcs);
        assert!(feat > 0);
    }

    #[test]
    fn empty_seed_batch() {
        let (store, spec) = setup();
        let sharded = ShardedStore::new(store, 2);
        let (graphs, stats) =
            sample_batch(&sharded, &spec, 1, &[], &RetryPolicy::default()).unwrap();
        assert!(graphs.is_empty());
        assert_eq!(stats.subgraphs, 0);
    }

    #[test]
    fn zero_max_attempts_is_an_error_not_a_clamp() {
        // Regression: max_attempts = 0 used to silently clamp to one
        // attempt; now it is a structured configuration error.
        let policy = RetryPolicy { max_attempts: 0 };
        let mut ran = false;
        let err = policy.run_ctx("shard 5", || {
            ran = true;
            Ok::<(), Error>(())
        });
        assert!(!ran, "f must never run under max_attempts = 0");
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("graph error"), "{msg}");
        assert!(msg.contains("shard 5"), "{msg}");
        assert!(msg.contains("max_attempts: 0"), "{msg}");
    }

    #[test]
    fn exhaustion_error_names_shard_and_attempts() {
        let policy = RetryPolicy { max_attempts: 4 };
        let err = policy
            .run_ctx("shard 2", || Err::<(), _>(Error::Sampler("transient".into())))
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("graph error"), "{err}");
        assert!(err.contains("shard 2"), "{err}");
        assert!(err.contains("after 4 attempts"), "{err}");
        assert!(err.contains("transient"), "{err}");
    }

    #[test]
    fn exhaustion_error_tallies_distinct_errors() {
        let policy = RetryPolicy { max_attempts: 3 };
        let mut i = 0;
        let err = policy
            .run_ctx("shard 1", || {
                i += 1;
                Err::<(), _>(if i == 1 {
                    Error::Sampler("transient".into())
                } else {
                    Error::Sampler("shard down".into())
                })
            })
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("after 3 attempts"), "{err}");
        assert!(err.contains("error tally"), "{err}");
        assert!(err.contains("1x sampler error: transient"), "{err}");
        assert!(err.contains("2x sampler error: shard down"), "{err}");
    }

    #[test]
    fn retry_metrics_count_attempts_and_exhaustions() {
        let reg = crate::obs::metrics::global();
        let attempts = reg.counter(crate::obs::metrics::names::SAMPLER_RETRY_ATTEMPTS);
        let exhausted = reg.counter(crate::obs::metrics::names::SAMPLER_RETRY_EXHAUSTED);
        let (a0, x0) = (attempts.get(), exhausted.get());
        let policy = RetryPolicy { max_attempts: 3 };
        let _ = policy.run_ctx("shard 9", || Err::<(), _>(Error::Sampler("transient".into())));
        // `>=`: other tests in this binary may be retrying concurrently.
        assert!(attempts.get() >= a0 + 3, "3 attempts counted");
        assert!(exhausted.get() >= x0 + 1, "1 exhaustion counted");
    }

    #[test]
    fn parallel_engine_matches_serial_oracle() {
        let (store, spec) = setup();
        let sharded = Arc::new(ShardedStore::new(store, 8));
        let seeds: Vec<u32> = (0..40).collect();
        let (want, wstats) =
            sample_batch(&sharded, &spec, 42, &seeds, &RetryPolicy::default()).unwrap();
        for threads in [2usize, 4, 8] {
            let cfg = SamplerConfig::with_threads(threads);
            let (got, stats) =
                sample_batch_parallel(&sharded, &spec, 42, &seeds, &cfg, None).unwrap();
            assert_eq!(got, want, "threads={threads}: bit-for-bit equal to serial");
            assert_eq!(stats.subgraphs, 40);
            assert_eq!(stats.seeds, wstats.seeds);
            assert_eq!(stats.frontier_entries, wstats.frontier_entries);
            assert_eq!(stats.adjacency_rpcs, wstats.adjacency_rpcs);
        }
    }

    #[test]
    fn parallel_engine_single_thread_delegates_to_serial() {
        let (store, spec) = setup();
        let sharded = Arc::new(ShardedStore::new(store, 4));
        let seeds: Vec<u32> = (0..12).collect();
        let cfg = SamplerConfig::with_threads(1);
        let (got, _) = sample_batch_parallel(&sharded, &spec, 9, &seeds, &cfg, None).unwrap();
        let (want, _) =
            sample_batch(&sharded, &spec, 9, &seeds, &RetryPolicy::default()).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_engine_resilient_to_transient_failures() {
        let (store, spec) = setup();
        let reliable = Arc::new(ShardedStore::new(store.clone(), 8));
        let flaky = Arc::new(ShardedStore::new(store, 8).with_failures(0.3, 77));
        let seeds: Vec<u32> = (0..25).collect();
        let (want, _) =
            sample_batch(&reliable, &spec, 3, &seeds, &RetryPolicy::default()).unwrap();
        let cfg = SamplerConfig {
            threads: 8,
            retry: RetryPolicy { max_attempts: 64 },
            ..SamplerConfig::default()
        };
        let (got, stats) = sample_batch_parallel(&flaky, &spec, 3, &seeds, &cfg, None).unwrap();
        assert_eq!(got, want, "identical output despite 30% transient shard failures");
        assert!(stats.retried_rpcs > 0, "failures actually happened and were retried");
    }

    #[test]
    fn parallel_engine_reuses_caller_pool() {
        let (store, spec) = setup();
        let sharded = Arc::new(ShardedStore::new(store, 4));
        let pool = ThreadPool::new(4);
        let seeds: Vec<u32> = (0..10).collect();
        let cfg = SamplerConfig::with_threads(4);
        let (a, _) =
            sample_batch_parallel(&sharded, &spec, 5, &seeds, &cfg, Some(&pool)).unwrap();
        let (b, _) =
            sample_batch_parallel(&sharded, &spec, 5, &seeds, &cfg, Some(&pool)).unwrap();
        assert_eq!(a, b, "same pool, same results — and the pool survives");
        let out = pool.map(vec![1usize, 2], |x| x + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn parallel_engine_fails_cleanly_when_retries_exhausted() {
        let (store, spec) = setup();
        let dead = Arc::new(ShardedStore::new(store, 2).with_failures(1.0, 5));
        let cfg = SamplerConfig {
            threads: 4,
            retry: RetryPolicy { max_attempts: 3 },
            ..SamplerConfig::default()
        };
        let err = sample_batch_parallel(&dead, &spec, 7, &[0, 1], &cfg, None);
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("after 3 attempts"), "{msg}");
        assert!(msg.contains("shard"), "{msg}");
    }
}
