//! Distributed sampling — **Algorithm 1** (paper §6.1.1).
//!
//! ```text
//! frontier_0 = Sample(S_0, E_p0)
//! for i in 1..=p.steps: frontier_i = Sample(frontier, E_pi)
//! edge_groups = frontier.GroupBy(sample_id)
//! edge_groups = DeduplicateNodes(edge_groups)
//! edges_with_features = lookup_features(edge_groups)
//! G = create_graph_tensors(edges_with_features)
//! ```
//!
//! [`sample_batch`] runs the plan **stage-wise over all seeds at once**
//! against the sharded store: each sampling op joins the current
//! frontier (a set of `(sample_id, node)` pairs) with one edge set, via
//! per-shard adjacency RPCs. Transient shard failures (injected by
//! [`crate::store::sharded::ShardedStore::with_failures`]) are retried
//! with bounded attempts — the resilience property §7 contrasts with
//! Graph-Learn. After expansion, edges are grouped by sample id, nodes
//! deduplicated, features joined, and GraphTensors assembled — shared
//! tail code with the in-memory sampler, which the equivalence tests
//! exploit.

use std::collections::BTreeMap;

use super::inmem::{edge_rng, select_neighbors};
use super::spec::SamplingSpec;
use super::{assemble_subgraph, validate_spec, EdgeAcc};
use crate::graph::GraphTensor;
use crate::store::sharded::ShardedStore;
use crate::{Error, Result};

/// Retry policy for shard RPCs.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub max_attempts: usize,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 8 }
    }
}

impl RetryPolicy {
    /// Run `f`, retrying transient failures up to the limit.
    pub fn run<T, F: FnMut() -> Result<T>>(&self, mut f: F) -> Result<T> {
        let mut last = None;
        for _ in 0..self.max_attempts.max(1) {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(Error::Sampler(format!(
            "RPC failed after {} attempts: {}",
            self.max_attempts,
            last.unwrap()
        )))
    }
}

/// Counters reported by a batch execution (per Fig. 4 pipeline stage).
#[derive(Debug, Default, Clone)]
pub struct SampleStats {
    pub seeds: usize,
    pub frontier_entries: usize,
    pub adjacency_rpcs: usize,
    pub retried_rpcs: usize,
    pub subgraphs: usize,
}

/// Execute the plan for a batch of seeds over the sharded store.
///
/// Stage-wise (all samples advance together, as the distributed join
/// does), deterministic per `plan_seed` regardless of scheduling.
pub fn sample_batch(
    store: &ShardedStore,
    spec: &SamplingSpec,
    plan_seed: u64,
    seeds: &[u32],
    retry: &RetryPolicy,
) -> Result<(Vec<GraphTensor>, SampleStats)> {
    let schema = &store.store().schema;
    validate_spec(schema, spec)?;
    let mut stats = SampleStats { seeds: seeds.len(), ..Default::default() };

    // produced[op_name][sample_idx] = nodes, first-seen order.
    let mut produced: BTreeMap<&str, Vec<Vec<u32>>> = BTreeMap::new();
    produced.insert(spec.seed_op.as_str(), seeds.iter().map(|&s| vec![s]).collect());
    // Per-sample edge accumulators.
    let mut edges: Vec<EdgeAcc> = seeds.iter().map(|_| EdgeAcc::new()).collect();

    for (op_idx, op) in spec.ops.iter().enumerate() {
        // Build the frontier for this op: per sample, the deduped union
        // of input-op outputs (first-occurrence order → deterministic).
        let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); seeds.len()];
        for (k, f) in frontier.iter_mut().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for input in &op.input_ops {
                if let Some(per_sample) = produced.get(input.as_str()) {
                    for &n in &per_sample[k] {
                        if seen.insert(n) {
                            f.push(n);
                        }
                    }
                }
            }
            stats.frontier_entries += f.len();
        }

        // Distributed Sample(): join frontier with the edge set.
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); seeds.len()];
        for (k, nodes) in frontier.iter().enumerate() {
            let mut out_seen = std::collections::HashSet::new();
            let acc = edges[k].entry(op.edge_set.clone()).or_default();
            for &node in nodes {
                stats.adjacency_rpcs += 1;
                let mut attempts = 0usize;
                let nbrs = retry.run(|| {
                    attempts += 1;
                    store.neighbors(&op.edge_set, node).map(|n| n.to_vec())
                })?;
                stats.retried_rpcs += attempts - 1;
                let mut rng = edge_rng(plan_seed, seeds[k], op_idx, node);
                for t in select_neighbors(&nbrs, op.sample_size, op.strategy, &mut rng) {
                    acc.push((node, t));
                    if out_seen.insert(t) {
                        out[k].push(t);
                    }
                }
            }
        }
        produced.insert(op.op_name.as_str(), out);
    }

    // GroupBy(sample_id) is implicit in the per-sample accumulators;
    // dedup + feature join + tensor creation per sample.
    let mut graphs = Vec::with_capacity(seeds.len());
    for (k, &seed) in seeds.iter().enumerate() {
        let g = assemble_subgraph(schema, &spec.seed_node_set, seed, &edges[k], |set, ids| {
            retry.run(|| store.lookup_features(set, ids))
        })?;
        graphs.push(g);
    }
    stats.subgraphs = graphs.len();
    Ok((graphs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::store::GraphStore;
    use crate::synth::mag::{generate, MagConfig};
    use std::sync::Arc;

    fn setup() -> (Arc<GraphStore>, SamplingSpec) {
        let ds = generate(&MagConfig::tiny());
        let spec = mag_sampling_spec_scaled(&ds.store.schema, 0.25).unwrap();
        (Arc::new(ds.store), spec)
    }

    #[test]
    fn equivalent_to_inmem_sampler() {
        // The cross-implementation invariant: Algorithm 1 over shards ==
        // single-threaded in-memory execution, bit for bit.
        let (store, spec) = setup();
        let inmem = InMemorySampler::new(store.clone(), spec.clone(), 42).unwrap();
        let sharded = ShardedStore::new(store.clone(), 4);
        let seeds: Vec<u32> = (0..30).collect();
        let (dist, stats) =
            sample_batch(&sharded, &spec, 42, &seeds, &RetryPolicy::default()).unwrap();
        assert_eq!(dist.len(), 30);
        assert_eq!(stats.subgraphs, 30);
        for (k, &s) in seeds.iter().enumerate() {
            assert_eq!(dist[k], inmem.sample(s).unwrap(), "seed {s}");
        }
    }

    #[test]
    fn resilient_to_transient_failures() {
        let (store, spec) = setup();
        let reliable = ShardedStore::new(store.clone(), 4);
        let flaky = ShardedStore::new(store.clone(), 4).with_failures(0.3, 999);
        let seeds: Vec<u32> = (0..20).collect();
        let (want, _) =
            sample_batch(&reliable, &spec, 7, &seeds, &RetryPolicy::default()).unwrap();
        let (got, stats) = sample_batch(&flaky, &spec, 7, &seeds, &RetryPolicy { max_attempts: 64 })
            .unwrap();
        assert_eq!(got, want, "results identical despite 30% transient failures");
        assert!(stats.retried_rpcs > 0, "failures actually happened and were retried");
    }

    #[test]
    fn fails_cleanly_when_retries_exhausted() {
        let (store, spec) = setup();
        // 100% failure: every request fails, retries can't save it.
        let dead = ShardedStore::new(store, 2).with_failures(1.0, 5);
        let err = sample_batch(&dead, &spec, 7, &[0, 1], &RetryPolicy { max_attempts: 3 });
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("after 3 attempts"), "{msg}");
    }

    #[test]
    fn stats_counted() {
        let (store, spec) = setup();
        let sharded = ShardedStore::new(store, 4);
        let seeds: Vec<u32> = (0..10).collect();
        let (_, stats) = sample_batch(&sharded, &spec, 1, &seeds, &RetryPolicy::default()).unwrap();
        assert_eq!(stats.seeds, 10);
        assert!(stats.adjacency_rpcs >= 10, "at least one expansion per seed");
        assert!(stats.frontier_entries >= stats.seeds);
        let (adj, feat, _) = sharded.total_requests();
        assert_eq!(adj as usize, stats.adjacency_rpcs);
        assert!(feat > 0);
    }

    #[test]
    fn empty_seed_batch() {
        let (store, spec) = setup();
        let sharded = ShardedStore::new(store, 2);
        let (graphs, stats) =
            sample_batch(&sharded, &spec, 1, &[], &RetryPolicy::default()).unwrap();
        assert!(graphs.is_empty());
        assert_eq!(stats.subgraphs, 0);
    }
}
