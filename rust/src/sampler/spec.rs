//! `SamplingSpecBuilder` (paper §8.2, Figure 6, appendix A.6.2).
//!
//! The builder produces a [`SamplingSpec`]: a seed op plus a DAG of
//! sampling ops, each naming its input ops, the edge set to expand
//! through, a sample size and a strategy. Op names follow the paper's
//! generated plan: `SEED->paper`, then `srcset->tgtset` for single-input
//! ops and `(in1|in2)->tgtset` for joins (A.6.2), with `#k` suffixes to
//! disambiguate repeats.

use crate::schema::GraphSchema;
use crate::util::json::{obj, str_arr, Json};
use crate::{Error, Result};

/// Neighbor sampling strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Uniform without replacement (the paper's RANDOM_UNIFORM).
    RandomUniform,
    /// Deterministic first-k by adjacency order (reproducible smoke
    /// tests; also how "top-k by stored rank" pipelines behave).
    TopK,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::RandomUniform => "RANDOM_UNIFORM",
            Strategy::TopK => "TOP_K",
        }
    }

    pub fn from_name(s: &str) -> Result<Strategy> {
        match s {
            "RANDOM_UNIFORM" => Ok(Strategy::RandomUniform),
            "TOP_K" => Ok(Strategy::TopK),
            other => Err(Error::Sampler(format!("unknown strategy {other:?}"))),
        }
    }
}

/// One sampling op (A.6.2's `sampling_ops` entry).
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingOp {
    pub op_name: String,
    pub input_ops: Vec<String>,
    pub edge_set: String,
    pub sample_size: usize,
    pub strategy: Strategy,
}

/// The full sampling plan.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingSpec {
    pub seed_op: String,
    pub seed_node_set: String,
    /// Topologically ordered (builder emits them in creation order).
    pub ops: Vec<SamplingOp>,
}

impl SamplingSpec {
    /// Serialize to JSON (the protobuf substitute).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "seed_op",
                obj(vec![
                    ("op_name", Json::Str(self.seed_op.clone())),
                    ("node_set_name", Json::Str(self.seed_node_set.clone())),
                ]),
            ),
            (
                "sampling_ops",
                Json::Arr(
                    self.ops
                        .iter()
                        .map(|op| {
                            obj(vec![
                                ("op_name", Json::Str(op.op_name.clone())),
                                ("input_op_names", str_arr(&op.input_ops)),
                                ("edge_set_name", Json::Str(op.edge_set.clone())),
                                ("sample_size", Json::Int(op.sample_size as i64)),
                                ("strategy", Json::Str(op.strategy.name().into())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SamplingSpec> {
        let seed = v.get("seed_op")?;
        let mut ops = Vec::new();
        for op in v.get("sampling_ops")?.as_arr()? {
            ops.push(SamplingOp {
                op_name: op.get("op_name")?.as_str()?.to_string(),
                input_ops: op
                    .get("input_op_names")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_str().map(|x| x.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                edge_set: op.get("edge_set_name")?.as_str()?.to_string(),
                sample_size: op.get("sample_size")?.as_usize()?,
                strategy: Strategy::from_name(op.get("strategy")?.as_str()?)?,
            });
        }
        Ok(SamplingSpec {
            seed_op: seed.get("op_name")?.as_str()?.to_string(),
            seed_node_set: seed.get("node_set_name")?.as_str()?.to_string(),
            ops,
        })
    }

    /// Total fan-out upper bound per seed (product along the widest
    /// path) — used by PadSpec derivation heuristics.
    pub fn max_nodes_per_seed(&self) -> usize {
        // Upper bound: each op contributes |inputs' bound| × sample_size.
        use std::collections::BTreeMap;
        let mut bound: BTreeMap<&str, usize> = BTreeMap::new();
        bound.insert(self.seed_op.as_str(), 1);
        let mut total = 1;
        for op in &self.ops {
            let in_bound: usize = op.input_ops.iter().map(|i| bound.get(i.as_str()).copied().unwrap_or(1)).sum();
            let produced = in_bound * op.sample_size;
            bound.insert(op.op_name.as_str(), produced);
            total += produced;
        }
        total
    }
}

/// A handle to one or more already-created ops, as returned by
/// `seed()` / `sample()` / `join()` — mirrors Figure 6's fluent API.
#[derive(Debug, Clone)]
pub struct OpHandle {
    /// Ops whose outputs this handle denotes.
    op_names: Vec<String>,
    /// Node set those ops produce.
    node_set: String,
}

/// Fluent builder for [`SamplingSpec`].
pub struct SamplingSpecBuilder {
    schema: GraphSchema,
    default_strategy: Strategy,
    state: std::cell::RefCell<BuilderState>,
}

struct BuilderState {
    seed_op: Option<(String, String)>,
    ops: Vec<SamplingOp>,
    used_names: std::collections::HashSet<String>,
}

impl SamplingSpecBuilder {
    pub fn new(schema: &GraphSchema, default_strategy: Strategy) -> SamplingSpecBuilder {
        SamplingSpecBuilder {
            schema: schema.clone(),
            default_strategy,
            state: std::cell::RefCell::new(BuilderState {
                seed_op: None,
                ops: Vec::new(),
                used_names: std::collections::HashSet::new(),
            }),
        }
    }

    /// Declare the seed node set ("Each paper node is a seed…").
    pub fn seed(&self, node_set: &str) -> Result<OpHandle> {
        self.schema.node_set(node_set)?;
        let name = format!("SEED->{node_set}");
        let mut st = self.state.borrow_mut();
        if st.seed_op.is_some() {
            return Err(Error::Sampler("seed() called twice".into()));
        }
        st.seed_op = Some((name.clone(), node_set.to_string()));
        st.used_names.insert(name.clone());
        Ok(OpHandle { op_names: vec![name], node_set: node_set.to_string() })
    }

    /// Sample up to `k` neighbors along `edge_set` from every node the
    /// handle denotes.
    pub fn sample(&self, from: &OpHandle, k: usize, edge_set: &str) -> Result<OpHandle> {
        let es = self.schema.edge_set(edge_set)?;
        if es.source != from.node_set {
            return Err(Error::Sampler(format!(
                "cannot sample {edge_set:?} (source {:?}) from nodes of {:?}",
                es.source, from.node_set
            )));
        }
        let mut st = self.state.borrow_mut();
        let base = if from.op_names.len() == 1 {
            format!("{}->{}", from.node_set, es.target)
        } else {
            format!("({})->{}", from.op_names.join("|"), es.target)
        };
        let mut name = base.clone();
        let mut n = 2;
        while st.used_names.contains(&name) {
            name = format!("{base}#{n}");
            n += 1;
        }
        st.used_names.insert(name.clone());
        st.ops.push(SamplingOp {
            op_name: name.clone(),
            input_ops: from.op_names.clone(),
            edge_set: edge_set.to_string(),
            sample_size: k,
            strategy: self.default_strategy,
        });
        Ok(OpHandle { op_names: vec![name], node_set: es.target.clone() })
    }

    /// Join handles over the same node set (Figure 6's
    /// `cited_papers.join([seed_paper])`).
    pub fn join(&self, handles: &[&OpHandle]) -> Result<OpHandle> {
        let Some(first) = handles.first() else {
            return Err(Error::Sampler("join of zero handles".into()));
        };
        let node_set = first.node_set.clone();
        let mut op_names = Vec::new();
        for h in handles {
            if h.node_set != node_set {
                return Err(Error::Sampler(format!(
                    "join over mixed node sets {:?} vs {:?}",
                    h.node_set, node_set
                )));
            }
            op_names.extend(h.op_names.iter().cloned());
        }
        Ok(OpHandle { op_names, node_set })
    }

    /// Finalize.
    pub fn build(&self) -> Result<SamplingSpec> {
        let st = self.state.borrow();
        let (seed_op, seed_node_set) = st
            .seed_op
            .clone()
            .ok_or_else(|| Error::Sampler("build() before seed()".into()))?;
        let spec = SamplingSpec { seed_op, seed_node_set, ops: st.ops.clone() };
        super::validate_spec(&self.schema, &spec)?;
        Ok(spec)
    }
}

/// The exact Figure 6 sampling program for OGBN-MAG.
pub fn mag_sampling_spec(schema: &GraphSchema) -> Result<SamplingSpec> {
    mag_sampling_spec_scaled(schema, 1.0)
}

/// Figure 6 with all fan-outs scaled by `f` (≥ epsilon) — small graphs
/// use f < 1 so subgraphs stay proportionate.
pub fn mag_sampling_spec_scaled(schema: &GraphSchema, f: f64) -> Result<SamplingSpec> {
    let k = |base: usize| ((base as f64 * f).round() as usize).max(1);
    let mut sizes = std::collections::BTreeMap::new();
    sizes.insert("cites".to_string(), k(32));
    sizes.insert("written".to_string(), k(8));
    sizes.insert("writes".to_string(), k(16));
    sizes.insert("affiliated_with".to_string(), k(16));
    sizes.insert("has_topic".to_string(), k(16));
    mag_sampling_spec_sized(schema, &sizes)
}

/// Figure 6's program with explicit per-edge-set fan-outs (the
/// `sampling.sizes` block of `configs/*.json`).
pub fn mag_sampling_spec_sized(
    schema: &GraphSchema,
    sizes: &std::collections::BTreeMap<String, usize>,
) -> Result<SamplingSpec> {
    let k = |es: &str| -> Result<usize> {
        sizes
            .get(es)
            .copied()
            .ok_or_else(|| Error::Sampler(format!("sampling sizes missing edge set {es:?}")))
    };
    let b = SamplingSpecBuilder::new(schema, Strategy::RandomUniform);
    // Each paper node is a seed for graph sampling.
    let seed_paper = b.seed("paper")?;
    // From each seed paper, sample cited papers.
    let cited_papers = b.sample(&seed_paper, k("cites")?, "cites")?;
    // From each paper (seed/cited), sample up to 8 authors.
    let authors = b.sample(&b.join(&[&cited_papers, &seed_paper])?, k("written")?, "written")?;
    // From these authors, sample up to 16 extra papers written by each.
    let author_papers = b.sample(&authors, k("writes")?, "writes")?;
    // From these authors, sample their affiliations.
    let _affils = b.sample(&authors, k("affiliated_with")?, "affiliated_with")?;
    // From each paper (seed/cited/written), sample topics.
    let _topics = b.sample(
        &b.join(&[&author_papers, &seed_paper, &cited_papers])?,
        k("has_topic")?,
        "has_topic",
    )?;
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::mag::{mag_schema, MagConfig};

    #[test]
    fn figure6_produces_a62_plan() {
        let schema = mag_schema(&MagConfig::tiny());
        let spec = mag_sampling_spec(&schema).unwrap();
        assert_eq!(spec.seed_op, "SEED->paper");
        assert_eq!(spec.seed_node_set, "paper");
        let names: Vec<&str> = spec.ops.iter().map(|o| o.op_name.as_str()).collect();
        // The exact generated plan of appendix A.6.2.
        assert_eq!(
            names,
            vec![
                "paper->paper",
                "(paper->paper|SEED->paper)->author",
                "author->paper",
                "author->institution",
                "(author->paper|SEED->paper|paper->paper)->field_of_study",
            ]
        );
        let authors_op = &spec.ops[1];
        assert_eq!(authors_op.input_ops, vec!["paper->paper", "SEED->paper"]);
        assert_eq!(authors_op.edge_set, "written");
        assert_eq!(authors_op.sample_size, 8);
        assert_eq!(authors_op.strategy, Strategy::RandomUniform);
        let topics_op = &spec.ops[4];
        assert_eq!(
            topics_op.input_ops,
            vec!["author->paper", "SEED->paper", "paper->paper"]
        );
        assert_eq!(topics_op.edge_set, "has_topic");
        assert_eq!(topics_op.sample_size, 16);
    }

    #[test]
    fn spec_json_roundtrip() {
        let schema = mag_schema(&MagConfig::tiny());
        let spec = mag_sampling_spec(&schema).unwrap();
        let json = spec.to_json();
        let spec2 = SamplingSpec::from_json(&json).unwrap();
        assert_eq!(spec, spec2);
    }

    #[test]
    fn wrong_source_set_rejected() {
        let schema = mag_schema(&MagConfig::tiny());
        let b = SamplingSpecBuilder::new(&schema, Strategy::RandomUniform);
        let seed = b.seed("paper").unwrap();
        // "writes" starts at author, not paper.
        assert!(b.sample(&seed, 4, "writes").is_err());
    }

    #[test]
    fn join_mixed_sets_rejected() {
        let schema = mag_schema(&MagConfig::tiny());
        let b = SamplingSpecBuilder::new(&schema, Strategy::RandomUniform);
        let seed = b.seed("paper").unwrap();
        let authors = b.sample(&seed, 4, "written").unwrap();
        assert!(b.join(&[&seed, &authors]).is_err());
    }

    #[test]
    fn duplicate_names_disambiguated() {
        let schema = mag_schema(&MagConfig::tiny());
        let b = SamplingSpecBuilder::new(&schema, Strategy::RandomUniform);
        let seed = b.seed("paper").unwrap();
        let c1 = b.sample(&seed, 4, "cites").unwrap();
        let c2 = b.sample(&seed, 8, "cites").unwrap();
        let spec = b.build().unwrap();
        assert_eq!(spec.ops[0].op_name, "paper->paper");
        assert_eq!(spec.ops[1].op_name, "paper->paper#2");
        let _ = (c1, c2);
    }

    #[test]
    fn seed_twice_rejected() {
        let schema = mag_schema(&MagConfig::tiny());
        let b = SamplingSpecBuilder::new(&schema, Strategy::RandomUniform);
        b.seed("paper").unwrap();
        assert!(b.seed("author").is_err());
    }

    #[test]
    fn max_nodes_per_seed_bound() {
        let schema = mag_schema(&MagConfig::tiny());
        let b = SamplingSpecBuilder::new(&schema, Strategy::RandomUniform);
        let seed = b.seed("paper").unwrap();
        let cited = b.sample(&seed, 4, "cites").unwrap();
        let _authors = b.sample(&b.join(&[&cited, &seed]).unwrap(), 2, "written").unwrap();
        let spec = b.build().unwrap();
        // 1 seed + 4 cited + (4+1)*2 authors = 15
        assert_eq!(spec.max_nodes_per_seed(), 15);
    }

    #[test]
    fn scaled_spec_minimum_one() {
        let schema = mag_schema(&MagConfig::tiny());
        let spec = mag_sampling_spec_scaled(&schema, 0.01).unwrap();
        assert!(spec.ops.iter().all(|o| o.sample_size >= 1));
    }
}
