//! Leader/worker coordination for the distributed sampler (Fig. 4).
//!
//! The leader shards the seed list into work items and hands them to a
//! fleet of worker threads; each worker runs Algorithm 1
//! ([`crate::sampler::distributed::sample_batch`]) against the sharded
//! store and returns GraphTensors, which the leader either collects in
//! memory or streams to shard files (§6.1.1: "each subgraph [is written]
//! to disk as an individual GraphTensor", randomly grouped into shards).
//!
//! Failure model: in addition to per-RPC transient failures (handled by
//! retries inside the worker), a worker can *crash* mid-item (simulated
//! via [`CoordinatorConfig::worker_crash_rate`]). The leader detects the
//! failed item and requeues it, up to `max_item_attempts` — TF-GNN's
//! "resilient distributed system" claim (§7), demonstrably unlike
//! training-stops-on-failure designs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::graph::GraphTensor;
use crate::sampler::distributed::{sample_batch, RetryPolicy, SampleStats};
use crate::sampler::spec::SamplingSpec;
use crate::store::sharded::ShardedStore;
use crate::util::rng::mix64;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub num_workers: usize,
    /// Seeds per work item.
    pub batch_size: usize,
    /// Probability a worker crashes while processing an item (simulated).
    pub worker_crash_rate: f64,
    /// Seed for the crash simulation stream.
    pub crash_seed: u64,
    /// Requeue limit per work item.
    pub max_item_attempts: usize,
    /// Per-RPC retry policy inside workers.
    pub rpc_retry: RetryPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            num_workers: 4,
            batch_size: 32,
            worker_crash_rate: 0.0,
            crash_seed: 0,
            max_item_attempts: 5,
            rpc_retry: RetryPolicy::default(),
        }
    }
}

/// Aggregate run report.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorReport {
    pub items: usize,
    pub requeues: u64,
    pub worker_crashes: u64,
    pub stats: SampleStats,
}

/// One unit of leader→worker work.
struct WorkItem {
    index: usize,
    seeds: Vec<u32>,
    attempt: usize,
}

/// Run the distributed sampling job: expand every seed, return the
/// subgraphs in seed order plus a run report.
pub fn run_sampling(
    store: Arc<ShardedStore>,
    spec: &SamplingSpec,
    plan_seed: u64,
    seeds: &[u32],
    cfg: &CoordinatorConfig,
) -> Result<(Vec<GraphTensor>, CoordinatorReport)> {
    assert!(cfg.num_workers > 0 && cfg.batch_size > 0);
    let items: Vec<WorkItem> = seeds
        .chunks(cfg.batch_size)
        .enumerate()
        .map(|(index, chunk)| WorkItem { index, seeds: chunk.to_vec(), attempt: 0 })
        .collect();
    let n_items = items.len();

    // Leader state: queue + results. Plain channels: workers pull work
    // items, push (index, result) back.
    let (work_tx, work_rx) = channel::<WorkItem>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (res_tx, res_rx) = channel::<(WorkItem, Result<(Vec<GraphTensor>, SampleStats)>)>();
    for item in items {
        work_tx
            .send(item)
            .map_err(|_| Error::Sampler("work queue closed before the job started".into()))?;
    }

    let crash_counter = Arc::new(AtomicU64::new(0));
    let spec = Arc::new(spec.clone());
    let mut workers = Vec::new();
    for w in 0..cfg.num_workers {
        let work_rx = Arc::clone(&work_rx);
        let res_tx = res_tx.clone();
        let store = Arc::clone(&store);
        let spec = Arc::clone(&spec);
        let crash_counter = Arc::clone(&crash_counter);
        let cfg = cfg.clone();
        let worker = std::thread::Builder::new()
            .name(format!("tfgnn-sampler-{w}"))
            .spawn(move || loop {
                let item = {
                    let rx =
                        work_rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    rx.recv()
                };
                let Ok(item) = item else { break };
                // Simulated crash: the worker abandons the item.
                if cfg.worker_crash_rate > 0.0 {
                    let n = crash_counter.fetch_add(1, Ordering::Relaxed);
                    let r = mix64(cfg.crash_seed, n) as f64 / u64::MAX as f64;
                    if r < cfg.worker_crash_rate {
                        let idx = item.index;
                        if res_tx
                            .send((
                                item,
                                Err(Error::Sampler(format!(
                                    "worker {w} crashed on item {idx} (injected)"
                                ))),
                            ))
                            .is_err()
                        {
                            break;
                        }
                        continue;
                    }
                }
                let result =
                    sample_batch(&store, &spec, plan_seed, &item.seeds, &cfg.rpc_retry);
                if res_tx.send((item, result)).is_err() {
                    break;
                }
            })?;
        workers.push(worker);
    }
    drop(res_tx);

    // Leader loop: collect results, requeue failures.
    let mut report = CoordinatorReport::default();
    let mut slots: Vec<Option<Vec<GraphTensor>>> = (0..n_items).map(|_| None).collect();
    let mut done = 0;
    while done < n_items {
        let (mut item, result) = res_rx
            .recv()
            .map_err(|_| Error::Sampler("all workers exited before completion".into()))?;
        match result {
            Ok((graphs, stats)) => {
                report.stats.seeds += stats.seeds;
                report.stats.frontier_entries += stats.frontier_entries;
                report.stats.adjacency_rpcs += stats.adjacency_rpcs;
                report.stats.retried_rpcs += stats.retried_rpcs;
                report.stats.subgraphs += stats.subgraphs;
                // A requeued item can in principle complete twice (the
                // original worker finishing after the requeue): keep
                // the first result and do NOT count `done` twice —
                // otherwise the loop could exit with another slot
                // still empty.
                if slots[item.index].is_none() {
                    slots[item.index] = Some(graphs);
                    done += 1;
                }
            }
            Err(e) => {
                report.worker_crashes += 1;
                item.attempt += 1;
                if item.attempt >= cfg.max_item_attempts {
                    // Shut the queue so workers drain and exit.
                    drop(work_tx);
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(Error::Sampler(format!(
                        "work item {} failed {} times; last error: {e}",
                        item.index, item.attempt
                    )));
                }
                report.requeues += 1;
                work_tx.send(item).map_err(|_| {
                    Error::Sampler("work queue closed while requeueing a failed item".into())
                })?;
            }
        }
    }
    drop(work_tx);
    for w in workers {
        let _ = w.join();
    }
    report.items = n_items;
    let graphs = collect_slots(slots)?;
    Ok((graphs, report))
}

/// Flatten the per-item result slots in seed order. An unfilled slot
/// means a worker died (or a bookkeeping bug dropped its result)
/// before the item completed — that is a structured error naming the
/// slot, never an `unwrap` panic deep in the leader.
fn collect_slots(slots: Vec<Option<Vec<GraphTensor>>>) -> Result<Vec<GraphTensor>> {
    let mut out = Vec::new();
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(graphs) => out.extend(graphs),
            None => {
                return Err(Error::Graph(format!(
                    "sampling work item slot {i} was never filled — its worker \
                     died before returning the item's subgraphs"
                )))
            }
        }
    }
    Ok(out)
}

/// Run sampling and stream results to shard files (the Fig. 4 bridge
/// from the sampling pipeline to training data on distributed storage).
pub fn run_sampling_to_shards(
    store: Arc<ShardedStore>,
    spec: &SamplingSpec,
    plan_seed: u64,
    seeds: &[u32],
    cfg: &CoordinatorConfig,
    dir: &std::path::Path,
    prefix: &str,
    num_shards: usize,
) -> Result<(crate::graph::io::ShardSet, CoordinatorReport)> {
    let (graphs, report) = run_sampling(store, spec, plan_seed, seeds, cfg)?;
    let set = crate::graph::io::ShardSet::write_all(dir, prefix, num_shards, graphs.into_iter())?;
    Ok((set, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig};

    fn setup() -> (Arc<ShardedStore>, SamplingSpec, Arc<crate::store::GraphStore>) {
        let ds = generate(&MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
        (Arc::new(ShardedStore::new(store.clone(), 4)), spec, store)
    }

    #[test]
    fn parallel_run_matches_inmem_in_seed_order() {
        let (sharded, spec, store) = setup();
        let seeds: Vec<u32> = (0..50).collect();
        let cfg = CoordinatorConfig { num_workers: 4, batch_size: 7, ..Default::default() };
        let (graphs, report) = run_sampling(sharded, &spec, 11, &seeds, &cfg).unwrap();
        assert_eq!(graphs.len(), 50);
        assert_eq!(report.items, 8);
        assert_eq!(report.stats.subgraphs, 50);
        let inmem = InMemorySampler::new(store, spec, 11).unwrap();
        for (k, &s) in seeds.iter().enumerate() {
            assert_eq!(graphs[k], inmem.sample(s).unwrap(), "seed {s}");
        }
    }

    #[test]
    fn survives_worker_crashes() {
        let (sharded, spec, store) = setup();
        let seeds: Vec<u32> = (0..40).collect();
        let cfg = CoordinatorConfig {
            num_workers: 3,
            batch_size: 5,
            worker_crash_rate: 0.4,
            crash_seed: 123,
            max_item_attempts: 50,
            ..Default::default()
        };
        let (graphs, report) = run_sampling(sharded, &spec, 5, &seeds, &cfg).unwrap();
        assert_eq!(graphs.len(), 40);
        assert!(report.worker_crashes > 0, "crashes actually injected");
        assert_eq!(report.requeues, report.worker_crashes);
        // Output identical to a crash-free run.
        let inmem = InMemorySampler::new(store, spec, 5).unwrap();
        for (k, &s) in seeds.iter().enumerate() {
            assert_eq!(graphs[k], inmem.sample(s).unwrap());
        }
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let (sharded, spec, _) = setup();
        let cfg = CoordinatorConfig {
            num_workers: 2,
            batch_size: 4,
            worker_crash_rate: 1.0, // every attempt crashes
            crash_seed: 1,
            max_item_attempts: 3,
            ..Default::default()
        };
        let err = run_sampling(sharded, &spec, 5, &(0..8).collect::<Vec<_>>(), &cfg);
        assert!(err.is_err());
        assert!(err.err().unwrap().to_string().contains("failed 3 times"));
    }

    /// Regression: an unfilled result slot (worker died before
    /// completing its item) must surface as a structured Error::Graph
    /// naming the slot — the old code `unwrap()`ed each slot and
    /// panicked the leader instead.
    #[test]
    fn missing_slot_is_structured_error_not_panic() {
        let (sharded, spec, _) = setup();
        let seeds: Vec<u32> = (0..6).collect();
        let cfg = CoordinatorConfig { num_workers: 2, batch_size: 3, ..Default::default() };
        let (graphs, _) =
            run_sampling(Arc::clone(&sharded), &spec, 11, &seeds, &cfg).unwrap();
        // Rebuild the leader's slot state with item 1 missing.
        let slots: Vec<Option<Vec<GraphTensor>>> = vec![Some(graphs), None];
        let err = collect_slots(slots).expect_err("missing slot must error");
        let msg = err.to_string();
        assert!(msg.contains("graph error"), "{msg}");
        assert!(msg.contains("slot 1"), "{msg}");
        assert!(msg.contains("worker"), "{msg}");
        // All-filled slots flatten in order.
        let a = collect_slots(vec![Some(Vec::new()), Some(Vec::new())]).unwrap();
        assert!(a.is_empty());
    }

    #[test]
    fn shard_output_roundtrip() {
        let (sharded, spec, _) = setup();
        let dir = std::env::temp_dir().join(format!("tfgnn-coord-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let seeds: Vec<u32> = (0..20).collect();
        let cfg = CoordinatorConfig { num_workers: 2, batch_size: 6, ..Default::default() };
        let (set, report) =
            run_sampling_to_shards(sharded, &spec, 2, &seeds, &cfg, &dir, "train", 3).unwrap();
        assert_eq!(report.stats.subgraphs, 20);
        assert_eq!(set.paths.len(), 3);
        assert_eq!(set.count().unwrap(), 20);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
