"""AOT lowering: JAX programs → HLO text + manifest (build-time only).

For every (config, arch) pair this emits four programs:

* ``init``       — () → params               (seeded inside)
* ``train_step`` — (params, m, v, step, batch) → (params', m', v', step',
                   loss, correct, weight)
* ``eval_step``  — (params, batch) → (loss, correct, weight)
* ``forward``    — (params, batch) → logits   (serving)

Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
64-bit instruction ids that the image's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

``artifacts/manifest.json`` records, per program, the ordered input and
output tensors (name, shape, dtype) so the Rust runtime marshals
literals without hard-coded signatures. Param slots are named
``param.<name>`` / ``adam_m.<name>`` / ``adam_v.<name>``; batch slots
follow ``ModelSpec.batch_spec()`` (``feat.*``, ``ids.*``, ``edge.*``,
``root.*``).

Usage:  python -m compile.aot --config ../configs/mag_small.json \
            --archs mpnn,mha --out ../artifacts
"""

import argparse
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def kept_inputs(lowered, inputs):
    """Filter the manifest input list down to the arguments jax kept.

    jit lowering prunes arguments that are dead in the optimized jaxpr
    (e.g. the last GraphUpdate's author-side weights in eval/forward:
    author states never reach the readout). The manifest must describe
    the *compiled* signature, so unused slots are dropped here.
    """
    kept = lowered._lowering.compile_args.get("kept_var_idx")
    if kept is None:
        return inputs
    return [t for i, t in enumerate(inputs) if i in kept]


def tensor_entry(name, aval):
    dtype = {"float32": "f32", "int32": "i32", "int64": "i64"}[str(aval.dtype)]
    return {"name": name, "shape": list(aval.shape), "dtype": dtype}


def lower_programs(spec: M.ModelSpec, arch: str):
    """Lower all four programs; returns {prog: (hlo_text, inputs, outputs)}."""
    seed = spec.train["init_seed"]
    params0 = M.init_params(spec, seed)
    names = list(params0.keys())
    batch_struct = spec.batch_struct()
    batch_names = list(batch_struct.keys())
    n = len(names)

    def pack_batch(flat):
        return dict(zip(batch_names, flat))

    # ---- init ----
    def init_fn():
        p = M.init_params(spec, seed)
        return tuple(p.values())

    init_lowered = jax.jit(init_fn).lower()
    init_inputs = []
    init_outputs = [tensor_entry(f"param.{k}", v) for k, v in params0.items()]

    # ---- train_step ----
    def train_fn(*args):
        params = dict(zip(names, args[:n]))
        m_state = dict(zip(names, args[n : 2 * n]))
        v_state = dict(zip(names, args[2 * n : 3 * n]))
        step = args[3 * n]
        hp = {
            "learning_rate": args[3 * n + 1],
            "dropout": args[3 * n + 2],
            "weight_decay": args[3 * n + 3],
        }
        batch = pack_batch(args[3 * n + 4 :])
        new_p, new_m, new_v, new_step, loss, correct, weight = M.train_step(
            spec, params, m_state, v_state, step, hp, batch
        )
        return (
            tuple(new_p[k] for k in names)
            + tuple(new_m[k] for k in names)
            + tuple(new_v[k] for k in names)
            + (new_step, loss, correct, weight)
        )

    param_structs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params0.values()]
    step_struct = jax.ShapeDtypeStruct((), jnp.int32)
    hp_struct = jax.ShapeDtypeStruct((), jnp.float32)
    train_args = (
        param_structs * 3 + [step_struct] + [hp_struct] * 3 + list(batch_struct.values())
    )
    train_lowered = jax.jit(train_fn).lower(*train_args)
    train_inputs = (
        [tensor_entry(f"param.{k}", v) for k, v in params0.items()]
        + [tensor_entry(f"adam_m.{k}", v) for k, v in params0.items()]
        + [tensor_entry(f"adam_v.{k}", v) for k, v in params0.items()]
        + [{"name": "step", "shape": [], "dtype": "i32"}]
        + [
            {"name": "hp.learning_rate", "shape": [], "dtype": "f32"},
            {"name": "hp.dropout", "shape": [], "dtype": "f32"},
            {"name": "hp.weight_decay", "shape": [], "dtype": "f32"},
        ]
        + [tensor_entry(k, v) for k, v in batch_struct.items()]
    )
    train_outputs = (
        [tensor_entry(f"param.{k}", v) for k, v in params0.items()]
        + [tensor_entry(f"adam_m.{k}", v) for k, v in params0.items()]
        + [tensor_entry(f"adam_v.{k}", v) for k, v in params0.items()]
        + [
            {"name": "step", "shape": [], "dtype": "i32"},
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "correct", "shape": [], "dtype": "f32"},
            {"name": "weight", "shape": [], "dtype": "f32"},
        ]
    )

    # ---- eval_step ----
    def eval_fn(*args):
        params = dict(zip(names, args[:n]))
        batch = pack_batch(args[n:])
        return M.eval_step(spec, params, batch)

    eval_args = param_structs + list(batch_struct.values())
    eval_lowered = jax.jit(eval_fn).lower(*eval_args)
    eval_inputs = [tensor_entry(f"param.{k}", v) for k, v in params0.items()] + [
        tensor_entry(k, v) for k, v in batch_struct.items()
    ]
    eval_outputs = [
        {"name": "loss", "shape": [], "dtype": "f32"},
        {"name": "correct", "shape": [], "dtype": "f32"},
        {"name": "weight", "shape": [], "dtype": "f32"},
    ]

    # ---- forward ----
    def forward_fn(*args):
        params = dict(zip(names, args[:n]))
        batch = pack_batch(args[n:])
        return (M.forward(spec, params, batch, train=False),)

    forward_lowered = jax.jit(forward_fn).lower(*eval_args)
    forward_outputs = [
        {
            "name": "logits",
            "shape": [spec.num_roots, spec.num_classes],
            "dtype": "f32",
        }
    ]

    return {
        "init": (to_hlo_text(init_lowered), init_inputs, init_outputs),
        "train_step": (
            to_hlo_text(train_lowered),
            kept_inputs(train_lowered, train_inputs),
            train_outputs,
        ),
        "eval_step": (
            to_hlo_text(eval_lowered),
            kept_inputs(eval_lowered, eval_inputs),
            eval_outputs,
        ),
        "forward": (
            to_hlo_text(forward_lowered),
            kept_inputs(forward_lowered, eval_inputs),
            forward_outputs,
        ),
    }, M.count_params(params0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=str(M.repo_root() / "configs/mag_small.json"))
    ap.add_argument("--archs", default="mpnn,mha")
    ap.add_argument("--out", default=str(M.repo_root() / "artifacts"))
    args = ap.parse_args()

    cfg = M.load_config(args.config)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cfg_name = cfg.get("name", Path(args.config).stem)

    manifest = {
        "config": cfg,
        "config_path": str(Path(args.config).resolve()),
        "models": {},
    }
    for arch in args.archs.split(","):
        arch = arch.strip()
        spec = M.ModelSpec(cfg, arch=arch)
        programs, n_params = lower_programs(spec, arch)
        entry = {
            "arch": arch,
            "hidden_dim": spec.model["hidden_dim"],
            "message_dim": spec.model["message_dim"],
            "num_layers": spec.model["num_layers"],
            "param_count": n_params,
            "programs": {},
        }
        for prog, (text, inputs, outputs) in programs.items():
            fname = f"{cfg_name}_{arch}_{prog}.hlo.txt"
            (out_dir / fname).write_text(text)
            entry["programs"][prog] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "inputs": inputs,
                "outputs": outputs,
            }
            print(f"wrote {fname}: {len(text)} chars, {len(inputs)} inputs")
        manifest["models"][arch] = entry

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"wrote manifest.json ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
