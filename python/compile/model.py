"""Layer-2: the heterogeneous GNN models in JAX (paper §4.2–4.3, §8.3).

Everything here follows the paper's GraphUpdate decomposition (Eq. 1–3):
per edge set a **Conv** computes and pools messages to receiver nodes;
per node set a **NextState** combines the old state with the pooled
messages. The receiver is the SOURCE endpoint, matching §8.3's sampled
subgraphs where edges point outward from the root ("NOTE: The receiver
is the source node from which the edge was sampled").

Model zoo (§4.3):
* ``mpnn``  — VanillaMPNN: relu(W [h_send ‖ h_recv]) messages, sum-pool
  (Figure 7/8); messages run through the **Pallas fused kernel**.
* ``sage``  — GraphSAGE: mean-pool of W·h_send.
* ``gcn``   — degree-normalized sum (Eq. 4 generalized per edge set).
* ``gatv2`` — GATv2 attention (Eq. A.4): per-head additive attention
  with segment softmax over each receiver's incoming edges.
* ``mha``   — Transformer-style dot-product multi-head attention; with
  larger dims this is the HGT-like high-capacity baseline of Table 1.

Hidden states: ``paper`` is encoded from its 128-d ``feat``; ``author``
starts at zero (computed from its neighborhood); ``institution`` and
``field_of_study`` are **embedding-table lookups keyed by original node
id** (§8.1: "train embedding tables for their representations over
time"), carried into the batch as the ``ids.<set>`` arrays.

Static shapes come from the PadSpec in ``configs/*.json``; padding
components are isolated by construction (no cross-component edges), so
correctness needs only the per-root mask in the loss.

Params are an ordered dict name→array; the same ordering (sorted names)
defines the AOT calling convention recorded in the manifest.
"""

import json
from collections import OrderedDict
from pathlib import Path

import jax
import jax.numpy as jnp

from compile.kernels import edge_conv, ref

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


def load_config(path):
    with open(path) as f:
        return json.load(f)


class ModelSpec:
    """Resolved model + batch layout for one (config, arch) pair."""

    def __init__(self, cfg, arch=None):
        self.cfg = cfg
        self.schema = cfg["schema"]
        self.pad = cfg["pad"]
        m = dict(cfg["model"])
        if arch is not None:
            m["arch"] = arch
        # High-capacity baseline: the Table-1 comparison point gets
        # wider dims, like HGT's 26.8M vs MPNN's 5.89M.
        if m["arch"] == "mha" and arch is not None:
            m.setdefault("hidden_dim_override", 256)
            m["hidden_dim"] = m.get("hidden_dim_override", 256)
            m["message_dim"] = m["hidden_dim"]
        self.model = m
        self.train = cfg["train"]
        self.batch_size = cfg["batch_size"]
        self.num_roots = self.pad["component_cap"] - 1
        self.num_classes = cfg["train"]["num_classes"]

    # ---- batch layout -----------------------------------------------------

    def batch_spec(self):
        """Ordered (name, shape, dtype) for the batch arguments."""
        out = []
        for set_name, ns in sorted(self.schema["node_sets"].items()):
            cap = self.pad["node_caps"][set_name]
            for feat_name, dim in sorted(ns.get("features", {}).items()):
                out.append((f"feat.{set_name}.{feat_name}", (cap, dim), "f32"))
            if ns.get("id_embedding", False):
                out.append((f"ids.{set_name}", (cap,), "i32"))
        for es_name in sorted(self.schema["edge_sets"].keys()):
            cap = self.pad["edge_caps"][es_name]
            out.append((f"edge.{es_name}.src", (cap,), "i32"))
            out.append((f"edge.{es_name}.tgt", (cap,), "i32"))
        out.append(("root.idx", (self.num_roots,), "i32"))
        out.append(("root.labels", (self.num_roots,), "i32"))
        out.append(("root.mask", (self.num_roots,), "f32"))
        return out

    def batch_struct(self):
        """ShapeDtypeStructs keyed by name."""
        dt = {"f32": jnp.float32, "i32": jnp.int32}
        return OrderedDict(
            (name, jax.ShapeDtypeStruct(shape, dt[dtype]))
            for name, shape, dtype in self.batch_spec()
        )


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_params(spec: ModelSpec, seed: int):
    """Ordered name→array parameter dict."""
    m = spec.model
    arch = m["arch"]
    d = m["hidden_dim"]
    dm = m["message_dim"]
    heads = m.get("num_heads", 4)
    key = jax.random.PRNGKey(seed)
    params = OrderedDict()

    def take():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    # Input encoders / embeddings.
    for set_name, ns in sorted(spec.schema["node_sets"].items()):
        feats = ns.get("features", {})
        for feat_name, dim in sorted(feats.items()):
            params[f"enc.{set_name}.{feat_name}.w"] = _glorot(take(), (dim, d))
            params[f"enc.{set_name}.{feat_name}.b"] = jnp.zeros((d,), jnp.float32)
        if ns.get("id_embedding", False):
            card = ns["cardinality"]
            params[f"emb.{set_name}"] = 0.05 * jax.random.normal(
                take(), (card, d), dtype=jnp.float32
            )

    # Per layer, per receiving node set, per edge set: conv weights.
    for layer in range(m["num_layers"]):
        for node_set, edge_list in sorted(m["updates"].items()):
            pooled_dim = 0
            for es in sorted(edge_list):
                p = f"l{layer}.{node_set}.{es}"
                if arch == "mpnn":
                    params[f"{p}.msg.w"] = _glorot(take(), (2 * d, dm))
                    params[f"{p}.msg.b"] = jnp.zeros((dm,), jnp.float32)
                    pooled_dim += dm
                elif arch in ("sage", "gcn"):
                    params[f"{p}.msg.w"] = _glorot(take(), (d, dm))
                    pooled_dim += dm
                elif arch == "gatv2":
                    dh = dm // heads
                    params[f"{p}.query.w"] = _glorot(take(), (d, heads * dh))
                    params[f"{p}.value.w"] = _glorot(take(), (d, heads * dh))
                    params[f"{p}.attn"] = _glorot(take(), (heads, dh))
                    pooled_dim += heads * dh
                elif arch == "mha":
                    dh = dm // heads
                    params[f"{p}.q.w"] = _glorot(take(), (d, heads * dh))
                    params[f"{p}.k.w"] = _glorot(take(), (d, heads * dh))
                    params[f"{p}.v.w"] = _glorot(take(), (d, heads * dh))
                    params[f"{p}.o.w"] = _glorot(take(), (heads * dh, dm))
                    pooled_dim += dm
                else:
                    raise ValueError(f"unknown arch {arch!r}")
            # NextState: concat(prev, pooled...) -> hidden.
            params[f"l{layer}.{node_set}.next.w"] = _glorot(take(), (d + pooled_dim, d))
            params[f"l{layer}.{node_set}.next.b"] = jnp.zeros((d,), jnp.float32)
            if m.get("use_layer_norm", False):
                params[f"l{layer}.{node_set}.ln.scale"] = jnp.ones((d,), jnp.float32)
                params[f"l{layer}.{node_set}.ln.bias"] = jnp.zeros((d,), jnp.float32)

    # Readout head.
    params["head.w"] = _glorot(take(), (d, spec.num_classes))
    params["head.b"] = jnp.zeros((spec.num_classes,), jnp.float32)
    return params


def count_params(params):
    return sum(int(p.size) for p in params.values())


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _segment_reduce(msgs, seg, n, how, use_pallas):
    if how == "sum":
        if use_pallas:
            return edge_conv.onehot_segment_sum(msgs, seg, n)
        return ref.segment_sum_ref(msgs, seg, n)
    if how == "mean":
        return ref.segment_mean_ref(msgs, seg, n)
    if how == "max":
        return ref.segment_max_ref(msgs, seg, n)
    raise ValueError(f"unknown reduce {how!r}")


def _conv(spec, params, prefix, arch, h_send, h_recv, src, tgt, n_recv, train_flags):
    """One Conv: messages on an edge set pooled to SOURCE nodes."""
    m = spec.model
    heads = m.get("num_heads", 4)
    reduce_type = m.get("reduce_type", "sum")
    use_pallas_seg = m.get("use_pallas_segment", False)
    sender = h_send[tgt]  # states at the far endpoint
    receiver = h_recv[src]
    if arch == "mpnn":
        if m.get("use_pallas_messages", True):
            msgs = edge_conv.fused_message(
                sender, receiver, params[f"{prefix}.msg.w"], params[f"{prefix}.msg.b"]
            )
        else:
            msgs = ref.fused_message_ref(
                sender, receiver, params[f"{prefix}.msg.w"], params[f"{prefix}.msg.b"]
            )
        return _segment_reduce(msgs, src, n_recv, reduce_type, use_pallas_seg)
    if arch == "sage":
        msgs = sender @ params[f"{prefix}.msg.w"]
        return _segment_reduce(msgs, src, n_recv, "mean", False)
    if arch == "gcn":
        # 1/sqrt(d_u d_v) normalization, Eq. (4) per edge set.
        ones = jnp.ones((src.shape[0], 1), jnp.float32)
        deg_recv = ref.segment_sum_ref(ones, src, n_recv)[:, 0] + 1.0
        deg_send = ref.segment_sum_ref(ones, tgt, h_send.shape[0])[:, 0] + 1.0
        norm = 1.0 / jnp.sqrt(deg_recv[src] * deg_send[tgt])
        msgs = (sender @ params[f"{prefix}.msg.w"]) * norm[:, None]
        return _segment_reduce(msgs, src, n_recv, "sum", use_pallas_seg)
    if arch == "gatv2":
        dh = m["message_dim"] // heads
        q = (receiver @ params[f"{prefix}.query.w"]).reshape(-1, heads, dh)
        v = (sender @ params[f"{prefix}.value.w"]).reshape(-1, heads, dh)
        feat = jax.nn.leaky_relu(q + v, negative_slope=0.2)
        logits = jnp.einsum("ehd,hd->eh", feat, params[f"{prefix}.attn"])
        alpha = ref.segment_softmax_ref(logits, src, n_recv)
        msgs = (v * alpha[..., None]).reshape(-1, heads * dh)
        return _segment_reduce(msgs, src, n_recv, "sum", use_pallas_seg)
    if arch == "mha":
        dh = m["message_dim"] // heads
        q = (receiver @ params[f"{prefix}.q.w"]).reshape(-1, heads, dh)
        k = (sender @ params[f"{prefix}.k.w"]).reshape(-1, heads, dh)
        v = (sender @ params[f"{prefix}.v.w"]).reshape(-1, heads, dh)
        logits = jnp.einsum("ehd,ehd->eh", q, k) / jnp.sqrt(float(dh))
        alpha = ref.segment_softmax_ref(logits, src, n_recv)
        msgs = (v * alpha[..., None]).reshape(-1, heads * dh)
        pooled = _segment_reduce(msgs, src, n_recv, "sum", use_pallas_seg)
        return pooled @ params[f"{prefix}.o.w"]
    raise ValueError(f"unknown arch {arch!r}")


def forward(spec: ModelSpec, params, batch, *, train: bool, dropout_key=None, dropout_rate=None):
    """Run the GNN; returns logits `[num_roots, num_classes]`.

    `dropout_rate` may be a traced scalar (the `hp.dropout` runtime
    input) — the A.6.3 sweep varies it without re-lowering.
    """
    m = spec.model
    arch = m["arch"]
    d = m["hidden_dim"]
    schema = spec.schema

    # Initial hidden states (MapFeatures).
    h = {}
    for set_name, ns in sorted(schema["node_sets"].items()):
        cap = spec.pad["node_caps"][set_name]
        feats = ns.get("features", {})
        if feats:
            state = jnp.zeros((cap, d), jnp.float32)
            for feat_name in sorted(feats):
                x = batch[f"feat.{set_name}.{feat_name}"]
                state = state + x @ params[f"enc.{set_name}.{feat_name}.w"]
            first = sorted(feats)[0]
            state = jax.nn.relu(state + params[f"enc.{set_name}.{first}.b"])
            h[set_name] = state
        elif ns.get("id_embedding", False):
            ids = batch[f"ids.{set_name}"]
            h[set_name] = params[f"emb.{set_name}"][ids]
        else:
            h[set_name] = jnp.zeros((cap, d), jnp.float32)

    if dropout_rate is None:
        dropout_rate = m.get("dropout", 0.0)
    use_dropout = train and dropout_key is not None

    # GraphUpdate rounds.
    for layer in range(m["num_layers"]):
        new_h = dict(h)
        for node_set, edge_list in sorted(m["updates"].items()):
            n_recv = spec.pad["node_caps"][node_set]
            pooled = []
            for es in sorted(edge_list):
                src = batch[f"edge.{es}.src"]
                tgt = batch[f"edge.{es}.tgt"]
                # receiver = SOURCE endpoint; sender = TARGET node set.
                send_set = schema["edge_sets"][es][1]
                pooled.append(
                    _conv(
                        spec,
                        params,
                        f"l{layer}.{node_set}.{es}",
                        arch,
                        h[send_set],
                        h[node_set],
                        src,
                        tgt,
                        n_recv,
                        train,
                    )
                )
            x = jnp.concatenate([h[node_set]] + pooled, axis=-1)
            x = jax.nn.relu(
                x @ params[f"l{layer}.{node_set}.next.w"]
                + params[f"l{layer}.{node_set}.next.b"]
            )
            if m.get("use_layer_norm", False):
                x = _layer_norm(
                    x,
                    params[f"l{layer}.{node_set}.ln.scale"],
                    params[f"l{layer}.{node_set}.ln.bias"],
                )
            if use_dropout:
                dropout_key, sub = jax.random.split(dropout_key)
                u = jax.random.uniform(sub, x.shape)
                keep = u >= dropout_rate
                x = jnp.where(keep, x / jnp.maximum(1.0 - dropout_rate, 1e-3), 0.0)
            new_h[node_set] = x
        h = new_h

    # Root readout (RootNodeMulticlassClassification).
    roots = h["paper"][batch["root.idx"]]
    return roots @ params["head.w"] + params["head.b"]


def loss_and_metrics(spec, params, batch, *, train, dropout_key=None, dropout_rate=None):
    """Masked softmax cross-entropy over root nodes + accuracy counts."""
    logits = forward(
        spec, params, batch, train=train, dropout_key=dropout_key, dropout_rate=dropout_rate
    )
    labels = batch["root.labels"]
    mask = batch["root.mask"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    weight = jnp.sum(mask)
    loss = jnp.sum(nll * mask) / jnp.maximum(weight, 1.0)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == labels).astype(jnp.float32) * mask)
    return loss, correct, weight


# ---------------------------------------------------------------------------
# Train step (Adam)
# ---------------------------------------------------------------------------


def train_step(spec: ModelSpec, params, m_state, v_state, step, hp, batch):
    """One fwd+bwd+Adam update. All-array signature for AOT.

    `hp` = {"learning_rate", "dropout", "weight_decay"} — runtime
    scalars so the sweep harness (A.6.3) varies them per trial without
    re-lowering.
    """
    t = spec.train
    lr = hp["learning_rate"]
    b1, b2, eps = t["adam_beta1"], t["adam_beta2"], t["adam_eps"]
    wd = hp["weight_decay"]
    dropout_key = jax.random.fold_in(jax.random.PRNGKey(t["init_seed"]), step)

    def loss_fn(p):
        loss, correct, weight = loss_and_metrics(
            spec, p, batch, train=True, dropout_key=dropout_key, dropout_rate=hp["dropout"]
        )
        return loss, (correct, weight)

    (loss, (correct, weight)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    new_step = step + 1
    tt = new_step.astype(jnp.float32)
    new_params = OrderedDict()
    new_m = OrderedDict()
    new_v = OrderedDict()
    for name in params:
        g = grads[name]
        if name.endswith(".w"):
            g = g + wd * params[name]
        mn = b1 * m_state[name] + (1.0 - b1) * g
        vn = b2 * v_state[name] + (1.0 - b2) * g * g
        mhat = mn / (1.0 - b1**tt)
        vhat = vn / (1.0 - b2**tt)
        new_params[name] = params[name] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[name] = mn
        new_v[name] = vn
    return new_params, new_m, new_v, new_step, loss, correct, weight


def eval_step(spec: ModelSpec, params, batch):
    return loss_and_metrics(spec, params, batch, train=False)


# ---------------------------------------------------------------------------
# Helpers for the AOT wrapper
# ---------------------------------------------------------------------------


def param_names(spec: ModelSpec, seed=0):
    return list(init_params(spec, seed).keys())


def repo_root():
    return Path(__file__).resolve().parents[2]
