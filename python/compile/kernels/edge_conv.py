"""Layer-1 Pallas kernels: the message-passing hot spot.

Three kernels, all `interpret=True` (the CPU PJRT plugin cannot run
Mosaic custom-calls; see /opt/xla-example/README.md):

* [`fused_message`] — the FLOP hot spot of MPNN-style convs (Eq. 3):
  ``relu(concat(sender, receiver) @ W + b)`` tiled over edge blocks.
  Both matmul operands are shaped for the MXU systolic array: the edge
  block is the M dimension (128-aligned), the feature dims K/N are the
  model dims (128/256). VMEM per block (see DESIGN.md §Perf):
  ``block_e*(2*Din) + 2*Din*Dout + block_e*Dout`` floats — ≈0.5 MiB at
  block_e=128, Din=Dout=256, comfortably inside a TensorCore's ~16 MiB.

* [`onehot_segment_sum`] — the TPU-idiomatic scatter: instead of CUDA
  atomics (what a GPU framework would use), each edge block contributes
  ``one_hot(seg_block).T @ data_block`` to the output, a dense MXU
  matmul. The grid iterates edge blocks sequentially and accumulates
  into the full output ref — the standard Pallas accumulation pattern.

* [`segment_softmax`] — attention normalization over incoming edges
  (GATv2 / MultiHeadAttention convs): runs the stable two-pass
  max/sum-shift entirely in VMEM for one edge block *after* per-segment
  max/sum have been reduced via the one-hot matmul trick.

The L2 model calls `fused_message` on the production path; the segment
ops default to `jax.ops.segment_sum` (an XLA scatter — faster under the
CPU interpreter) and can be flipped to the Pallas variants with
`use_pallas_segment` in the model config. Numerics of both paths are
asserted equal in pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Edge-block tile. 128 matches both the MXU systolic dimension and the
# f32 VPU lane tiling (8, 128).
BLOCK_E = 128


def _ceil_div(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# fused_message
# ---------------------------------------------------------------------------


def _fused_message_kernel(sender_ref, receiver_ref, w_ref, b_ref, out_ref):
    s = sender_ref[...]
    r = receiver_ref[...]
    x = jnp.concatenate([s, r], axis=-1)
    y = x @ w_ref[...] + b_ref[...][None, :]
    out_ref[...] = jnp.maximum(y, 0.0)


def _fused_message_impl(sender, receiver, w, b, block_e=BLOCK_E):
    e, din = sender.shape
    dout = w.shape[1]
    assert w.shape[0] == 2 * din, (w.shape, din)
    if e <= block_e or e % block_e != 0:
        # Unaligned edge caps run as one block (PadSpecs should prefer
        # 128-multiples; see DESIGN.md §Perf).
        grid = (1,)
        block_e = e
    else:
        grid = (e // block_e,)
    return pl.pallas_call(
        _fused_message_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, din), lambda i: (i, 0)),
            pl.BlockSpec((block_e, din), lambda i: (i, 0)),
            pl.BlockSpec((2 * din, dout), lambda i: (0, 0)),
            pl.BlockSpec((dout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_e, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, dout), sender.dtype),
        interpret=True,
    )(sender, receiver, w, b)


@jax.custom_vjp
def fused_message(sender, receiver, w, b):
    """relu(concat([sender, receiver], -1) @ w + b), tiled over edges.

    sender/receiver: [E, Din]; w: [2*Din, Dout]; b: [Dout] -> [E, Dout].
    E must be a multiple of BLOCK_E if E > BLOCK_E (the AOT pad specs
    guarantee MXU-aligned edge caps); small E runs as a single block.

    Differentiable: the forward pass is the Pallas kernel; the backward
    pass is the analytic VJP of relu∘affine (dense matmuls that XLA
    fuses on its own — the fwd kernel's relu mask is reused as the
    residual, so no recomputation of the affine part).
    """
    return _fused_message_impl(sender, receiver, w, b)


def _fused_message_fwd(sender, receiver, w, b):
    out = _fused_message_impl(sender, receiver, w, b)
    return out, (sender, receiver, w, out)


def _fused_message_bwd(res, g):
    sender, receiver, w, out = res
    din = sender.shape[1]
    gm = jnp.where(out > 0, g, 0.0)  # relu mask
    x = jnp.concatenate([sender, receiver], axis=-1)
    gw = x.T @ gm
    gb = jnp.sum(gm, axis=0)
    gx = gm @ w.T
    return gx[:, :din], gx[:, din:], gw, gb


fused_message.defvjp(_fused_message_fwd, _fused_message_bwd)


# ---------------------------------------------------------------------------
# onehot_segment_sum
# ---------------------------------------------------------------------------


def _onehot_segment_sum_kernel(data_ref, seg_ref, out_ref, *, num_segments):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    data = data_ref[...]
    seg = seg_ref[...]
    onehot = (seg[:, None] == jnp.arange(num_segments)[None, :]).astype(data.dtype)
    out_ref[...] += onehot.T @ data


def onehot_segment_sum(data, segment_ids, num_segments, *, block_e=BLOCK_E):
    """Segment sum via per-block one-hot matmuls (MXU scatter).

    data: [E, D]; segment_ids: int32 [E] -> [num_segments, D].
    """
    e, d = data.shape
    if e <= block_e or e % block_e != 0:
        grid = (1,)
        block_e = e
    else:
        grid = (e // block_e,)
    kernel = functools.partial(_onehot_segment_sum_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        # Every grid step maps to the whole output -> sequential
        # accumulation across edge blocks.
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), data.dtype),
        interpret=True,
    )(data, segment_ids.astype(jnp.int32))


# ---------------------------------------------------------------------------
# segment_softmax
# ---------------------------------------------------------------------------


def _segment_softmax_kernel(logits_ref, seg_ref, maxs_ref, sums_ref, out_ref):
    logits = logits_ref[...]
    seg = seg_ref[...]
    shifted = jnp.exp(logits - maxs_ref[...][seg])
    out_ref[...] = shifted / jnp.maximum(sums_ref[...][seg], 1e-38)


def segment_softmax(logits, segment_ids, num_segments, *, block_e=BLOCK_E):
    """Stable softmax of [E] logits within segments.

    Two reduction passes run as jnp one-hot matmuls (MXU-friendly); the
    normalization pass is the Pallas kernel, tiled over edge blocks with
    the per-segment max/sum tables resident in VMEM.
    """
    e = logits.shape[0]
    seg = segment_ids.astype(jnp.int32)
    onehot = (seg[:, None] == jnp.arange(num_segments)[None, :]).astype(logits.dtype)
    # Per-segment max (empty segments -> 0, same as ref/rust).
    neg = jnp.finfo(logits.dtype).min
    maxs = jnp.max(jnp.where(onehot > 0, logits[:, None], neg), axis=0)
    maxs = jnp.where(jnp.isfinite(maxs), maxs, 0.0)
    exp = jnp.exp(logits - maxs[seg])
    sums = onehot.T @ exp

    if e <= block_e or e % block_e != 0:
        grid = (1,)
        block_e = e
    else:
        grid = (e // block_e,)
    return pl.pallas_call(
        _segment_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((num_segments,), lambda i: (0,)),
            pl.BlockSpec((num_segments,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), logits.dtype),
        interpret=True,
    )(logits, seg, maxs, sums)
