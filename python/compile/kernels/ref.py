"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness ground truth*: `pytest python/tests` sweeps
shapes and data (hypothesis) asserting the Pallas kernels match these to
float tolerance, and the Rust `ops::segment` module mirrors the same
semantics on the other side of the AOT boundary.
"""

import jax
import jax.numpy as jnp


def fused_message_ref(sender, receiver, w, b):
    """relu(concat([sender, receiver], -1) @ w + b).

    sender, receiver: [E, Din]; w: [2*Din, Dout]; b: [Dout] -> [E, Dout].
    The per-edge message computation of Eq. (3) / Figure 7's MyConv.
    """
    x = jnp.concatenate([sender, receiver], axis=-1)
    return jax.nn.relu(x @ w + b)


def segment_sum_ref(data, segment_ids, num_segments):
    """Sum rows of `data` [E, D] by segment id -> [num_segments, D]."""
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean_ref(data, segment_ids, num_segments):
    sums = segment_sum_ref(data, segment_ids, num_segments)
    counts = jax.ops.segment_sum(
        jnp.ones((data.shape[0],), data.dtype), segment_ids, num_segments=num_segments
    )
    return sums / jnp.maximum(counts, 1.0)[:, None]


def segment_max_ref(data, segment_ids, num_segments):
    """Max by segment; only *empty* segments yield 0 (matches rust ops).

    Legitimate non-finite inputs pass through: a segment holding -inf
    reports -inf, and NaN inputs poison their segment (like a
    sequential reduce_max). Zeroing every non-finite output — the old
    behaviour — silently rewrote real data; rust's
    ``ops::segment::segment_max`` tracks per-segment counts for the
    same reason.
    """
    out = jax.ops.segment_max(data, segment_ids, num_segments=num_segments)
    counts = jax.ops.segment_sum(
        jnp.ones((data.shape[0],), jnp.float32), segment_ids, num_segments=num_segments
    )
    empty = counts == 0.0
    if out.ndim > 1:
        empty = empty[:, None]
    # NaN stickiness: segment_max ignores NaN under unordered compares,
    # so re-poison any segment that received one.
    has_nan = (
        jax.ops.segment_sum(
            jnp.isnan(data).astype(jnp.float32), segment_ids, num_segments=num_segments
        )
        > 0.0
    )
    out = jnp.where(has_nan, jnp.nan, out)
    return jnp.where(empty, 0.0, out)


def segment_softmax_ref(logits, segment_ids, num_segments):
    """Numerically stable softmax within segments.

    logits: [E] or [E, H]; returns same shape. Rows of one segment sum
    to 1 (per trailing column).
    """
    squeeze = logits.ndim == 1
    if squeeze:
        logits = logits[:, None]
    maxs = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    maxs = jnp.where(jnp.isfinite(maxs), maxs, 0.0)
    shifted = logits - maxs[segment_ids]
    exp = jnp.exp(shifted)
    sums = jax.ops.segment_sum(exp, segment_ids, num_segments=num_segments)
    out = exp / jnp.maximum(sums[segment_ids], 1e-38)
    return out[:, 0] if squeeze else out


def onehot_segment_sum_ref(data, segment_ids, num_segments):
    """The MXU formulation: one_hot(seg).T @ data — identical result to
    segment_sum_ref, used to cross-check the TPU-idiomatic kernel."""
    onehot = jax.nn.one_hot(segment_ids, num_segments, dtype=data.dtype)
    return onehot.T @ data
