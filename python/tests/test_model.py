"""L2 correctness: model shapes, gradients, learning, arch zoo."""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import model as M  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

CFG_PATH = Path(__file__).resolve().parents[2] / "configs/mag_small.json"


def tiny_cfg():
    """Shrunk config so tests run fast."""
    cfg = M.load_config(CFG_PATH)
    cfg["pad"] = {
        "node_caps": {"paper": 24, "author": 16, "institution": 8, "field_of_study": 8},
        "edge_caps": {
            "cites": 16,
            "writes": 16,
            "written": 16,
            "affiliated_with": 8,
            "has_topic": 16,
        },
        "component_cap": 3,
    }
    cfg["batch_size"] = 2
    cfg["schema"]["node_sets"]["paper"]["features"]["feat"] = 12
    cfg["model"]["hidden_dim"] = 16
    cfg["model"]["message_dim"] = 16
    cfg["model"]["num_layers"] = 2
    cfg["model"]["num_heads"] = 2
    cfg["train"]["num_classes"] = 4
    cfg["schema"]["node_sets"]["institution"]["cardinality"] = 10
    cfg["schema"]["node_sets"]["field_of_study"]["cardinality"] = 10
    return cfg


def random_batch(spec, key, n_classes=4):
    """A structurally valid padded batch: 2 real components + padding.

    Component layout per node set: [comp0 | comp1 | padding]; edges stay
    inside their component, mirroring the Rust pad() output.
    """
    batch = {}
    rngs = jax.random.split(key, 64)
    ri = iter(range(64))

    def nk():
        return rngs[next(ri)]

    caps_n = spec.pad["node_caps"]
    # Nodes per component (2 real + 1 pad): fixed simple split.
    comp_nodes = {}
    for set_name, cap in caps_n.items():
        per = cap // 3
        comp_nodes[set_name] = [(0, per), (per, 2 * per), (2 * per, cap)]

    for name, struct in spec.batch_struct().items():
        if name.startswith("feat."):
            batch[name] = jax.random.normal(nk(), struct.shape, jnp.float32)
        elif name.startswith("ids."):
            set_name = name.split(".")[1]
            card = spec.schema["node_sets"][set_name]["cardinality"]
            batch[name] = jax.random.randint(nk(), struct.shape, 0, card, jnp.int32)
        elif name.startswith("edge."):
            es = name.split(".")[1]
            endpoint = name.split(".")[2]
            src_set, tgt_set = spec.schema["edge_sets"][es]
            set_name = src_set if endpoint == "src" else tgt_set
            cap_e = struct.shape[0]
            per_comp = cap_e // 3
            vals = []
            for c in range(3):
                lo, hi = comp_nodes[set_name][c]
                n = per_comp if c < 2 else cap_e - 2 * per_comp
                vals.append(jax.random.randint(nk(), (n,), lo, hi, jnp.int32))
            batch[name] = jnp.concatenate(vals)
        elif name == "root.idx":
            batch[name] = jnp.array(
                [comp_nodes["paper"][0][0], comp_nodes["paper"][1][0]], jnp.int32
            )
        elif name == "root.labels":
            batch[name] = jax.random.randint(nk(), struct.shape, 0, n_classes, jnp.int32)
        elif name == "root.mask":
            batch[name] = jnp.ones(struct.shape, jnp.float32)
    return batch


ARCHS = ["mpnn", "sage", "gcn", "gatv2", "mha"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    spec = M.ModelSpec(tiny_cfg(), arch=arch)
    params = M.init_params(spec, 0)
    batch = random_batch(spec, jax.random.PRNGKey(1))
    logits = M.forward(spec, params, batch, train=False)
    assert logits.shape == (spec.num_roots, spec.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_gradients_flow_everywhere(arch):
    spec = M.ModelSpec(tiny_cfg(), arch=arch)
    params = M.init_params(spec, 0)
    batch = random_batch(spec, jax.random.PRNGKey(2))

    def loss_fn(p):
        loss, _, _ = M.loss_and_metrics(spec, p, batch, train=False)
        return loss

    grads = jax.grad(loss_fn)(params)
    dead = [
        k
        for k, g in grads.items()
        if not np.isfinite(np.asarray(g)).all()
    ]
    assert not dead, f"non-finite grads: {dead}"
    # Head and at least the last layer must receive signal.
    assert np.abs(np.asarray(grads["head.w"])).max() > 0
    some_layer = [k for k in grads if k.startswith("l1.")]
    assert any(np.abs(np.asarray(grads[k])).max() > 0 for k in some_layer)


def test_mask_zeroes_padding_roots():
    spec = M.ModelSpec(tiny_cfg(), arch="mpnn")
    params = M.init_params(spec, 0)
    batch = random_batch(spec, jax.random.PRNGKey(3))
    l_full, c_full, w_full = M.loss_and_metrics(spec, params, batch, train=False)
    # Mask out root 1: loss must now equal the root-0-only loss.
    batch2 = dict(batch)
    batch2["root.mask"] = jnp.array([1.0, 0.0])
    l_masked, c_masked, w_masked = M.loss_and_metrics(spec, params, batch2, train=False)
    assert w_full == 2.0 and w_masked == 1.0
    assert c_masked <= c_full
    assert np.isfinite(l_masked)


def test_padding_nodes_do_not_affect_real_roots():
    # Perturb features of the padding component only: logits at real
    # roots must not change (component isolation, §3.2).
    spec = M.ModelSpec(tiny_cfg(), arch="mpnn")
    params = M.init_params(spec, 0)
    batch = random_batch(spec, jax.random.PRNGKey(4))
    logits1 = M.forward(spec, params, batch, train=False)
    batch2 = dict(batch)
    feat = np.asarray(batch["feat.paper.feat"]).copy()
    cap = spec.pad["node_caps"]["paper"]
    feat[2 * (cap // 3):] += 100.0  # padding component rows
    batch2["feat.paper.feat"] = jnp.asarray(feat)
    logits2 = M.forward(spec, params, batch2, train=False)
    np.testing.assert_allclose(logits1, logits2, rtol=1e-5, atol=1e-5)


def test_train_step_reduces_loss_overfit():
    # A few Adam steps on one batch must reduce loss (sanity that the
    # whole fwd+bwd+opt pipeline learns).
    spec = M.ModelSpec(tiny_cfg(), arch="mpnn")
    params = M.init_params(spec, 0)
    m_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    v_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    step = jnp.asarray(0, jnp.int32)
    batch = random_batch(spec, jax.random.PRNGKey(5))

    hp = {"learning_rate": 1e-3, "dropout": 0.0, "weight_decay": 0.0}
    step_fn = jax.jit(lambda p, m, v, s: M.train_step(spec, p, m, v, s, hp, batch))
    losses = []
    for _ in range(30):
        params, m_state, v_state, step, loss, correct, weight = step_fn(
            params, m_state, v_state, step
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"
    assert int(step) == 30


def test_param_counts_ordered_and_stable():
    spec = M.ModelSpec(tiny_cfg(), arch="mpnn")
    p1 = M.init_params(spec, 0)
    p2 = M.init_params(spec, 0)
    assert list(p1.keys()) == list(p2.keys())
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
    p3 = M.init_params(spec, 1)
    assert any(
        not np.array_equal(np.asarray(p1[k]), np.asarray(p3[k])) for k in p1
    ), "different seed, different params"


def test_mha_is_higher_capacity_than_mpnn():
    # Table 1's premise: the attention baseline has several times the
    # parameters of the tuned MPNN.
    cfg = M.load_config(CFG_PATH)
    mpnn = M.ModelSpec(cfg, arch="mpnn")
    mha = M.ModelSpec(cfg, arch="mha")
    n_mpnn = M.count_params(M.init_params(mpnn, 0))
    n_mha = M.count_params(M.init_params(mha, 0))
    assert n_mha > 2 * n_mpnn, f"mha {n_mha} vs mpnn {n_mpnn}"


def test_pallas_and_ref_message_paths_agree():
    cfg = tiny_cfg()
    cfg["model"]["use_pallas_messages"] = True
    spec_pallas = M.ModelSpec(cfg, arch="mpnn")
    cfg2 = tiny_cfg()
    cfg2["model"]["use_pallas_messages"] = False
    spec_ref = M.ModelSpec(cfg2, arch="mpnn")
    params = M.init_params(spec_pallas, 0)
    batch = random_batch(spec_pallas, jax.random.PRNGKey(6))
    out_pallas = M.forward(spec_pallas, params, batch, train=False)
    out_ref = M.forward(spec_ref, params, batch, train=False)
    np.testing.assert_allclose(out_pallas, out_ref, rtol=1e-4, atol=1e-5)


def test_pallas_segment_path_agrees():
    cfg = tiny_cfg()
    cfg["model"]["use_pallas_segment"] = True
    spec_a = M.ModelSpec(cfg, arch="mpnn")
    cfg2 = tiny_cfg()
    cfg2["model"]["use_pallas_segment"] = False
    spec_b = M.ModelSpec(cfg2, arch="mpnn")
    params = M.init_params(spec_a, 0)
    batch = random_batch(spec_a, jax.random.PRNGKey(7))
    np.testing.assert_allclose(
        M.forward(spec_a, params, batch, train=False),
        M.forward(spec_b, params, batch, train=False),
        rtol=1e-4,
        atol=1e-5,
    )


def test_batch_spec_matches_struct():
    spec = M.ModelSpec(tiny_cfg(), arch="mpnn")
    names = [n for n, _, _ in spec.batch_spec()]
    assert names == list(spec.batch_struct().keys())
    assert "root.idx" in names and "edge.cites.src" in names
    assert names.index("edge.cites.src") < names.index("edge.cites.tgt")
