"""AOT emission checks: manifest ↔ programs ↔ model consistency."""

import json
import sys
from pathlib import Path

import jax
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import aot, model as M  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

ROOT = Path(__file__).resolve().parents[2]
ARTIFACTS = ROOT / "artifacts"


def small_spec():
    cfg = M.load_config(ROOT / "configs/mag_small.json")
    cfg["pad"] = {
        "node_caps": {"paper": 16, "author": 8, "institution": 8, "field_of_study": 8},
        "edge_caps": {
            "cites": 8,
            "writes": 8,
            "written": 8,
            "affiliated_with": 8,
            "has_topic": 8,
        },
        "component_cap": 3,
    }
    cfg["schema"]["node_sets"]["paper"]["features"]["feat"] = 8
    cfg["model"]["hidden_dim"] = 8
    cfg["model"]["message_dim"] = 8
    cfg["model"]["num_layers"] = 1
    return M.ModelSpec(cfg, arch="mpnn")


def test_lower_programs_emits_all_four():
    spec = small_spec()
    programs, n_params = aot.lower_programs(spec, "mpnn")
    assert set(programs) == {"init", "train_step", "eval_step", "forward"}
    assert n_params > 0
    for name, (text, inputs, outputs) in programs.items():
        assert "ENTRY" in text, name
        assert outputs, name
    # train_step inputs = 3 × params + step + 3 hp + batch, minus any
    # dead arguments jax pruned (the manifest records the *compiled*
    # signature; see aot.kept_inputs).
    n_batch = len(spec.batch_spec())
    text, inputs, outputs = programs["train_step"]
    n_leaves = len(M.init_params(spec, 0))
    full = 3 * n_leaves + 1 + 3 + n_batch
    assert len(inputs) <= full
    assert len(inputs) >= n_leaves + n_batch, "params+batch mostly kept"
    names = [i["name"] for i in inputs]
    assert "step" in names and "hp.learning_rate" in names
    assert len(outputs) == 3 * n_leaves + 1 + 3
    # init has no inputs and one output per param leaf.
    _, init_in, init_out = programs["init"]
    assert init_in == []
    assert len(init_out) == n_leaves


def test_manifest_on_disk_consistent():
    manifest_path = ARTIFACTS / "manifest.json"
    if not manifest_path.exists():
        pytest.skip("run `make artifacts` first")
    manifest = json.loads(manifest_path.read_text())
    assert "mpnn" in manifest["models"]
    for arch, entry in manifest["models"].items():
        for prog, p in entry["programs"].items():
            f = ARTIFACTS / p["file"]
            assert f.exists(), f
            text = f.read_text()
            assert "ENTRY" in text
            # Input names unique and ordered param->adam->step->batch.
            names = [i["name"] for i in p["inputs"]]
            assert len(names) == len(set(names)), f"dup inputs in {prog}"
            if prog == "train_step":
                kinds = [n.split(".")[0] for n in names]
                first_batch = next(
                    i for i, k in enumerate(kinds) if k in ("feat", "ids", "edge", "root")
                )
                assert "step" in names
                assert all(
                    k in ("param", "adam_m", "adam_v", "step", "hp")
                    for k in kinds[:first_batch]
                )

    # Table-1 premise recorded in the manifest: mha ≫ mpnn params.
    if "mha" in manifest["models"]:
        assert (
            manifest["models"]["mha"]["param_count"]
            > 2 * manifest["models"]["mpnn"]["param_count"]
        )


def test_batch_layout_matches_rust_convention():
    # The Rust runtime derives literals from these exact names.
    spec = small_spec()
    names = [n for n, _, _ in spec.batch_spec()]
    assert names[-3:] == ["root.idx", "root.labels", "root.mask"]
    for es in spec.schema["edge_sets"]:
        assert f"edge.{es}.src" in names
        assert f"edge.{es}.tgt" in names
    assert "feat.paper.feat" in names
    assert "ids.institution" in names
    assert "ids.field_of_study" in names
    assert "ids.paper" not in names
