"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes-in-range data and segment patterns;
`assert_allclose` against `ref.py`. This is the core L1 signal the
DESIGN.md test strategy calls for.
"""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import edge_conv, ref  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, minval=lo, maxval=hi, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# fused_message
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    e=st.sampled_from([1, 3, 64, 128, 256, 384]),
    din=st.sampled_from([4, 16, 128]),
    dout=st.sampled_from([8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_message_matches_ref(e, din, dout, seed):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    sender = rand(k[0], (e, din))
    receiver = rand(k[1], (e, din))
    w = rand(k[2], (2 * din, dout), -0.5, 0.5)
    b = rand(k[3], (dout,), -0.5, 0.5)
    got = edge_conv.fused_message(sender, receiver, w, b)
    want = ref.fused_message_ref(sender, receiver, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_message_rejects_unaligned():
    k = jax.random.PRNGKey(0)
    sender = rand(k, (130, 8))
    try:
        edge_conv.fused_message(sender, sender, rand(k, (16, 8)), rand(k, (8,)))
        assert False, "should reject E=130 (not block-aligned, > block)"
    except AssertionError as e:
        assert "aligned" in str(e) or "should reject" not in str(e)


def test_fused_message_zero_weights_give_bias_relu():
    e, din, dout = 128, 4, 4
    sender = jnp.ones((e, din))
    receiver = jnp.ones((e, din))
    w = jnp.zeros((2 * din, dout))
    b = jnp.array([-1.0, 0.0, 0.5, 2.0])
    out = edge_conv.fused_message(sender, receiver, w, b)
    np.testing.assert_allclose(out, jnp.tile(jnp.array([0.0, 0.0, 0.5, 2.0]), (e, 1)))


# ---------------------------------------------------------------------------
# onehot_segment_sum
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    e=st.sampled_from([1, 5, 128, 256]),
    d=st.sampled_from([1, 8, 64]),
    n=st.sampled_from([1, 4, 50]),
    seed=st.integers(0, 2**31 - 1),
)
def test_onehot_segment_sum_matches_ref(e, d, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    data = rand(k1, (e, d))
    seg = jax.random.randint(k2, (e,), 0, n)
    got = edge_conv.onehot_segment_sum(data, seg, n)
    want = ref.segment_sum_ref(data, seg, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # And the oracle's two formulations agree with each other.
    np.testing.assert_allclose(
        ref.onehot_segment_sum_ref(data, seg, n), want, rtol=1e-5, atol=1e-5
    )


def test_onehot_segment_sum_empty_segments_zero():
    data = jnp.ones((128, 3))
    seg = jnp.zeros((128,), jnp.int32)  # everything in segment 0
    out = edge_conv.onehot_segment_sum(data, seg, 4)
    np.testing.assert_allclose(out[0], jnp.full((3,), 128.0))
    np.testing.assert_allclose(out[1:], jnp.zeros((3, 3)))


def test_onehot_segment_sum_multiblock_accumulates():
    # 3 blocks of 128; all rows into segment 1.
    data = jnp.ones((384, 2))
    seg = jnp.ones((384,), jnp.int32)
    out = edge_conv.onehot_segment_sum(data, seg, 2)
    np.testing.assert_allclose(out[1], jnp.full((2,), 384.0))
    np.testing.assert_allclose(out[0], jnp.zeros((2,)))


# ---------------------------------------------------------------------------
# segment_softmax
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    e=st.sampled_from([1, 7, 128, 256]),
    n=st.sampled_from([1, 3, 40]),
    scale=st.sampled_from([1.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_softmax_matches_ref(e, n, scale, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = rand(k1, (e,), -scale, scale)
    seg = jax.random.randint(k2, (e,), 0, n)
    got = edge_conv.segment_softmax(logits, seg, n)
    want = ref.segment_softmax_ref(logits, seg, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_segment_softmax_sums_to_one():
    k = jax.random.PRNGKey(3)
    logits = rand(k, (256,), -5, 5)
    seg = jax.random.randint(jax.random.PRNGKey(4), (256,), 0, 10)
    w = edge_conv.segment_softmax(logits, seg, 10)
    sums = ref.segment_sum_ref(w[:, None], seg, 10)[:, 0]
    counts = ref.segment_sum_ref(jnp.ones((256, 1)), seg, 10)[:, 0]
    np.testing.assert_allclose(sums[counts > 0], 1.0, rtol=1e-5)


def test_segment_softmax_stability_large_logits():
    logits = jnp.array([1000.0, 1001.0] + [0.0] * 126)
    seg = jnp.array([0, 0] + [1] * 126, jnp.int32)
    w = edge_conv.segment_softmax(logits, seg, 2)
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_allclose(w[0] + w[1], 1.0, rtol=1e-5)
    assert w[1] > w[0]


# ---------------------------------------------------------------------------
# kernels inside jit / grad (they must lower into the AOT graph)
# ---------------------------------------------------------------------------


def test_fused_message_jits_and_differentiates():
    e, din, dout = 128, 8, 4
    k = jax.random.split(jax.random.PRNGKey(1), 4)
    sender = rand(k[0], (e, din))
    receiver = rand(k[1], (e, din))
    w = rand(k[2], (2 * din, dout))
    b = rand(k[3], (dout,))

    def loss(w, b):
        return jnp.sum(edge_conv.fused_message(sender, receiver, w, b) ** 2)

    def loss_ref(w, b):
        return jnp.sum(ref.fused_message_ref(sender, receiver, w, b) ** 2)

    gw, gb = jax.jit(jax.grad(loss, argnums=(0, 1)))(w, b)
    gw_ref, gb_ref = jax.grad(loss_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, gb_ref, rtol=1e-4, atol=1e-5)


def test_kernels_lower_to_hlo_text():
    # The AOT path: kernels must survive lowering to HLO text.
    from jax._src.lib import xla_client as xc

    def fn(s, r, w, b):
        return (edge_conv.fused_message(s, r, w, b),)

    spec = [
        jax.ShapeDtypeStruct((128, 8), jnp.float32),
        jax.ShapeDtypeStruct((128, 8), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    ]
    lowered = jax.jit(fn).lower(*spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    assert "ENTRY" in text and len(text) > 100
